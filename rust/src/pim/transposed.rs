//! Transposed-write ReRAM array (paper Fig. 3b; Wan ISSCC'20) — the FM
//! engine's storage fabric.
//!
//! A conventional array programs operands row by row, stalling the
//! producer behind `rows × write_pulse`. The transposed array accepts a
//! whole vector as ONE column-parallel pulse, so the EFC layer's output
//! vectors stream straight in ("aligns spatially with the inputs and
//! eliminates idle buffers", §3.2). Once populated:
//!
//! * ones-vector wordline read → per-column sums Σ_n x_n;
//! * reading with each stored vector itself → Σ_n x_n² on the bit lines
//!   (concurrently — the two reductions share the pass).

use super::config::PimConfig;
use super::crossbar::XbarActivity;

/// Functional + event-counting model of one transposed array of
/// `d` wordlines × `n_slots` column slots holding d-dim vectors.
pub struct TransposedArray {
    pub d: usize,
    pub n_slots: usize,
    /// column-major storage: slot s holds vector[0..d]
    slots: Vec<Vec<f32>>,
    pub activity: XbarActivity,
}

impl TransposedArray {
    pub fn new(d: usize, n_slots: usize) -> TransposedArray {
        TransposedArray {
            d,
            n_slots,
            slots: Vec::new(),
            activity: XbarActivity::default(),
        }
    }

    /// Column-parallel write of one vector (ONE programming pulse).
    pub fn write_vector(&mut self, v: &[f32]) {
        assert_eq!(v.len(), self.d, "vector dim mismatch");
        assert!(self.slots.len() < self.n_slots, "array full");
        self.slots.push(v.to_vec());
        self.activity.write_pulses += 1;
        self.activity.cells_written += self.d as u64;
    }

    pub fn occupied(&self) -> usize {
        self.slots.len()
    }

    pub fn reset(&mut self) {
        self.slots.clear();
    }

    /// Ones-vector read: Σ_n x_n per wordline (d-dim result). One analog
    /// cycle + one ADC conversion per wordline group.
    pub fn read_sum(&mut self, cfg: &PimConfig) -> Vec<f64> {
        self.activity.read_cycles += 1;
        self.activity.adc_conversions += (self.d.div_ceil(cfg.xbar) * self.d.min(cfg.xbar)) as u64;
        let mut out = vec![0f64; self.d];
        for s in &self.slots {
            for (o, &v) in out.iter_mut().zip(s.iter()) {
                *o += v as f64;
            }
        }
        out
    }

    /// Self-read: Σ_n x_n² — each stored vector drives the wordlines
    /// against itself; bit-line accumulation sums the squares. The paper
    /// overlaps this with `read_sum` (same pass), which the pipeline
    /// model accounts for; functionally it is a separate reduction.
    pub fn read_sum_squares(&mut self, cfg: &PimConfig) -> Vec<f64> {
        self.activity.read_cycles += self.slots.len() as u64;
        self.activity.adc_conversions +=
            (self.slots.len() * self.d.div_ceil(cfg.xbar).max(1)) as u64;
        let mut out = vec![0f64; self.d];
        for s in &self.slots {
            for (o, &v) in out.iter_mut().zip(s.iter()) {
                *o += (v as f64) * (v as f64);
            }
        }
        out
    }

    /// Full FM interaction for the stored vectors:
    /// 0.5 · ((Σx)² − Σx²), the MBSA performing the square.
    pub fn fm_interaction(&mut self, cfg: &PimConfig, mbsa: &mut super::mbsa::Mbsa) -> Vec<f64> {
        let s = self.read_sum(cfg);
        let ss = self.read_sum_squares(cfg);
        let s2 = mbsa.square_vector(&s);
        s2.iter()
            .zip(&ss)
            .map(|(a, b)| 0.5 * (a - b))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pim::mbsa::Mbsa;
    use crate::util::rng::Rng;

    #[test]
    fn fm_matches_pairwise_definition() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(5);
        let (n, d) = (6, 8);
        let vecs: Vec<Vec<f32>> = (0..n)
            .map(|_| (0..d).map(|_| rng.normal() as f32).collect())
            .collect();
        let mut arr = TransposedArray::new(d, n);
        for v in &vecs {
            arr.write_vector(v);
        }
        let mut mbsa = Mbsa::new(d, 16);
        let got = arr.fm_interaction(&cfg, &mut mbsa);
        // explicit Σ_{i<j} x_i ⊙ x_j
        let mut want = vec![0f64; d];
        for i in 0..n {
            for j in (i + 1)..n {
                for t in 0..d {
                    want[t] += vecs[i][t] as f64 * vecs[j][t] as f64;
                }
            }
        }
        for t in 0..d {
            assert!((got[t] - want[t]).abs() < 1e-4, "{t}: {} vs {}", got[t], want[t]);
        }
    }

    #[test]
    fn writes_are_single_pulse_per_vector() {
        let mut arr = TransposedArray::new(16, 4);
        arr.write_vector(&vec![1.0; 16]);
        arr.write_vector(&vec![2.0; 16]);
        assert_eq!(arr.activity.write_pulses, 2);
        assert_eq!(arr.activity.cells_written, 32);
    }

    #[test]
    #[should_panic(expected = "array full")]
    fn overflow_panics() {
        let mut arr = TransposedArray::new(4, 1);
        arr.write_vector(&[0.0; 4]);
        arr.write_vector(&[0.0; 4]);
    }

    #[test]
    fn reset_allows_reuse() {
        let cfg = PimConfig::default();
        let mut arr = TransposedArray::new(4, 2);
        arr.write_vector(&[1.0; 4]);
        arr.reset();
        assert_eq!(arr.occupied(), 0);
        arr.write_vector(&[2.0; 4]);
        let s = arr.read_sum(&cfg);
        assert_eq!(s, vec![2.0; 4]);
    }
}
