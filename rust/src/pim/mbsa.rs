//! MBSA — multiplication-by-bit-serial-AND array (Zheng DAC'23), used by
//! the FM engine to square the Σx vector (paper Fig. 4e).
//!
//! Operation: the multiplicand vector is programmed into the array once;
//! then each bit of the multiplier is broadcast to the AND gates and the
//! partial products are shift-accumulated. Squaring v means multiplier =
//! multiplicand = v, so the cycle count is the bit-width of v's fixed
//! point representation.

/// Functional + cost-counting MBSA model.
pub struct Mbsa {
    /// lanes (vector elements processed in parallel)
    pub lanes: usize,
    /// fixed-point bits used for the bit-serial multiply
    pub bits: usize,
    /// total bit-cycles executed (for the cost layer)
    pub cycles: u64,
    /// total lane-operations (energy proxy)
    pub lane_ops: u64,
}

impl Mbsa {
    pub fn new(lanes: usize, bits: usize) -> Mbsa {
        Mbsa {
            lanes,
            bits,
            cycles: 0,
            lane_ops: 0,
        }
    }

    /// Square every element of `v` via bit-serial AND accumulation.
    ///
    /// Functionally this is exact elementwise squaring: the fixed-point
    /// bit loop reconstructs the product exactly for values representable
    /// in `bits` bits; we model the numerics at f64 precision (the
    /// quantization of interest already happened at the ADC) and count
    /// the cycles the bit-serial loop would take.
    pub fn square_vector(&mut self, v: &[f64]) -> Vec<f64> {
        let waves = v.len().div_ceil(self.lanes).max(1);
        self.cycles += (self.bits * waves) as u64;
        self.lane_ops += (self.bits * v.len()) as u64;
        v.iter().map(|&x| x * x).collect()
    }

    /// Elementwise multiply (general MBSA use; the FM engine only needs
    /// squares but the DP naive-mapping baseline reuses this).
    pub fn mul_vectors(&mut self, a: &[f64], b: &[f64]) -> Vec<f64> {
        assert_eq!(a.len(), b.len());
        let waves = a.len().div_ceil(self.lanes).max(1);
        self.cycles += (self.bits * waves) as u64;
        self.lane_ops += (self.bits * a.len()) as u64;
        a.iter().zip(b).map(|(x, y)| x * y).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn squares_exactly() {
        let mut m = Mbsa::new(8, 16);
        let v = vec![1.5, -2.0, 0.0, 3.25];
        assert_eq!(m.square_vector(&v), vec![2.25, 4.0, 0.0, 10.5625]);
    }

    #[test]
    fn cycle_count_scales_with_bits_and_waves() {
        let mut m = Mbsa::new(4, 8);
        m.square_vector(&vec![0.0; 8]); // 2 waves × 8 bits
        assert_eq!(m.cycles, 16);
        assert_eq!(m.lane_ops, 64);
        m.square_vector(&vec![0.0; 2]); // 1 wave
        assert_eq!(m.cycles, 24);
    }

    #[test]
    fn mul_matches_elementwise() {
        let mut m = Mbsa::new(8, 8);
        let got = m.mul_vectors(&[2.0, 3.0], &[4.0, -1.0]);
        assert_eq!(got, vec![8.0, -3.0]);
    }
}
