//! Behavioral simulator (paper §4.1: "we develop a behavioral simulator
//! to further analyze end-to-end latency and throughput").
//!
//! Model: each mapped op owns its tile (dedicated silicon — the mapper
//! already allocated arrays per op), so contention is *pipelining*
//! across requests: a tile accepts a new request every `bottleneck_ns`
//! (initiation interval) and completes it `latency_ns` after acceptance.
//! The embedding memory tiles are a shared front-end resource whose
//! initiation interval is the bank-conflict-limited gather time.
//!
//! The schedule is computed as a deterministic discrete-event sweep in
//! topological order (deps always have lower ids — enforced by the
//! mapper), which is equivalent to an event-heap simulation for this
//! DAG-with-pipelined-resources model but allocation-free on the hot
//! path (this simulator runs inside the evolutionary search loop).

use crate::embeddings::{GatherCost, MemoryTileModel, Placement};
use crate::mapping::MappedModel;
use crate::util::rng::Rng;
use crate::util::stats::Quantiles;

/// End-to-end simulation report (one workload on one design).
#[derive(Clone, Debug)]
pub struct SimReport {
    pub design: String,
    pub n_requests: usize,
    /// mean / p99 end-to-end request latency
    pub latency_ns_mean: f64,
    pub latency_ns_p99: f64,
    /// steady-state throughput (inferences / second)
    pub throughput_rps: f64,
    /// energy per inference (pJ) — dynamic only
    pub energy_pj_per_inf: f64,
    /// average power over the run (mW), dynamic + leakage
    pub power_mw: f64,
    /// compute-tile silicon area (mm²) — Table 3's area row compares
    /// compute tiles (all designs share the same embedding storage)
    pub area_mm2: f64,
    /// embedding memory-tile area (mm²); contributes to power
    pub mem_area_mm2: f64,
    /// power efficiency: inferences / s / W
    pub inf_per_s_per_w: f64,
    /// simulated wall-clock of the whole run (ns)
    pub makespan_ns: f64,
}

impl SimReport {
    pub fn speedup_vs(&self, other: &SimReport) -> f64 {
        self.throughput_rps / other.throughput_rps
    }

    pub fn power_eff_vs(&self, other: &SimReport) -> f64 {
        self.inf_per_s_per_w / other.inf_per_s_per_w
    }

    pub fn area_saving_vs(&self, other: &SimReport) -> f64 {
        other.area_mm2 / self.area_mm2
    }
}

/// Workload description for a simulation run.
#[derive(Clone, Debug)]
pub struct Workload {
    pub n_requests: usize,
    /// requests arriving per second (Poisson-ish via uniform jitter);
    /// `f64::INFINITY` = closed-loop (back-to-back, measures capacity)
    pub arrival_rps: f64,
    pub seed: u64,
}

impl Default for Workload {
    fn default() -> Self {
        Workload {
            n_requests: 256,
            arrival_rps: f64::INFINITY,
            seed: 7,
        }
    }
}

/// The embedding front-end seen by the simulator.
pub struct EmbeddingFrontend<'a> {
    pub tiles: &'a MemoryTileModel,
    pub placement: &'a Placement,
    /// per-request gather cost sampler (field heads vary per request)
    pub gather: GatherCost,
}

/// Simulate `workload` on a mapped model with an embedding front-end.
pub fn simulate(
    model: &MappedModel,
    frontend: Option<&EmbeddingFrontend<'_>>,
    workload: &Workload,
) -> SimReport {
    let n_ops = model.ops.len();
    let mut tile_free = vec![0f64; n_ops];
    let mut gather_free = 0f64;
    let mut done = vec![0f64; n_ops];
    let mut rng = Rng::new(workload.seed);
    let mut lat = Quantiles::new();
    let mut makespan = 0f64;
    let mut dyn_energy = 0f64;

    let inter_arrival_ns = if workload.arrival_rps.is_finite() {
        1e9 / workload.arrival_rps
    } else {
        0.0
    };
    let mut arrive = 0f64;

    let (gather_lat, gather_energy) = frontend
        .map(|f| (f.gather.latency_ns, f.gather.energy_pj))
        .unwrap_or((0.0, 0.0));

    for _ in 0..workload.n_requests {
        // Request arrival (jittered open loop or closed loop).
        if inter_arrival_ns > 0.0 {
            arrive += inter_arrival_ns * (0.5 + rng.f64());
        }
        // Embedding gather: shared front-end, initiation-interval =
        // gather latency (banks are busy for the whole conflict chain).
        let g_start = arrive.max(gather_free);
        let g_done = g_start + gather_lat;
        gather_free = g_start + gather_lat;
        dyn_energy += gather_energy;

        // Op DAG in topological order (dep id < op id).
        for (i, op) in model.ops.iter().enumerate() {
            let deps_done = op
                .deps
                .iter()
                .map(|&d| done[d])
                .fold(g_done, f64::max);
            let start = deps_done.max(tile_free[i]);
            done[i] = start + op.cost.latency_ns;
            tile_free[i] = start + op.cost.bottleneck_ns.max(1e-3);
            dyn_energy += op.cost.energy_pj;
        }
        let finish = done.last().copied().unwrap_or(g_done);
        lat.push(finish - arrive);
        makespan = makespan.max(finish);
    }

    let n = workload.n_requests;
    let throughput = n as f64 / (makespan.max(1e-9) / 1e9);
    let leakage_mw = model.leakage_mw
        + frontend.map(|f| f.tiles.leakage_mw).unwrap_or(0.0);
    let mem_area = frontend.map(|f| f.tiles.area_mm2).unwrap_or(0.0);
    // Whole-chip static floor (clock/NoC/controller; params.rs) over
    // compute AND storage silicon.
    let chip_static_mw = (model.area_mm2 + mem_area)
        * crate::pim::TechParams::default().static_mw_per_mm2;
    let power_mw = dyn_energy / makespan.max(1e-9) + leakage_mw + chip_static_mw;
    SimReport {
        design: format!("{}:{:?}", model.genome_name, model.style),
        n_requests: n,
        latency_ns_mean: lat.quantile(0.5),
        latency_ns_p99: lat.p99(),
        throughput_rps: throughput,
        energy_pj_per_inf: dyn_energy / n as f64,
        power_mw,
        area_mm2: model.area_mm2,
        mem_area_mm2: mem_area,
        inf_per_s_per_w: throughput / (power_mw / 1e3),
        makespan_ns: makespan,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::{map_genome, MapStyle};
    use crate::nas::genome::{autorac_best, nasrec_like};
    use crate::pim::TechParams;

    fn sim(style: MapStyle, genome: fn(&str) -> crate::nas::Genome) -> SimReport {
        let tech = TechParams::default();
        let m = map_genome(&genome("criteo"), &tech, style).unwrap();
        simulate(&m, None, &Workload::default())
    }

    #[test]
    fn throughput_exceeds_inverse_latency_when_pipelined() {
        let r = sim(MapStyle::Smart, autorac_best);
        let serial_rps = 1e9 / r.latency_ns_mean;
        assert!(
            r.throughput_rps > 1.5 * serial_rps,
            "pipelining should overlap requests: {} vs serial {}",
            r.throughput_rps,
            serial_rps
        );
    }

    #[test]
    fn smart_design_beats_naive_end_to_end() {
        let smart = sim(MapStyle::Smart, autorac_best);
        let naive = sim(MapStyle::Naive, nasrec_like);
        assert!(smart.speedup_vs(&naive) > 1.5, "{}", smart.speedup_vs(&naive));
        assert!(smart.power_eff_vs(&naive) > 1.0);
    }

    #[test]
    fn open_loop_latency_grows_with_load() {
        let tech = TechParams::default();
        let m = map_genome(&autorac_best("criteo"), &tech, MapStyle::Smart).unwrap();
        let capacity = simulate(&m, None, &Workload::default()).throughput_rps;
        let light = simulate(
            &m,
            None,
            &Workload {
                arrival_rps: capacity * 0.2,
                ..Default::default()
            },
        );
        let heavy = simulate(
            &m,
            None,
            &Workload {
                arrival_rps: capacity * 0.95,
                ..Default::default()
            },
        );
        assert!(heavy.latency_ns_p99 >= light.latency_ns_p99);
    }

    #[test]
    fn energy_per_inference_is_load_independent() {
        let tech = TechParams::default();
        let m = map_genome(&autorac_best("criteo"), &tech, MapStyle::Smart).unwrap();
        let a = simulate(&m, None, &Workload { n_requests: 64, ..Default::default() });
        let b = simulate(&m, None, &Workload { n_requests: 512, ..Default::default() });
        assert!((a.energy_pj_per_inf - b.energy_pj_per_inf).abs() < 1e-6);
    }

    #[test]
    fn makespan_is_monotone_in_requests() {
        let tech = TechParams::default();
        let m = map_genome(&autorac_best("criteo"), &tech, MapStyle::Smart).unwrap();
        let a = simulate(&m, None, &Workload { n_requests: 32, ..Default::default() });
        let b = simulate(&m, None, &Workload { n_requests: 320, ..Default::default() });
        assert!(b.makespan_ns > a.makespan_ns);
        // and throughput converges to steady state (within 2×)
        assert!(b.throughput_rps < 2.0 * a.throughput_rps);
    }

    #[test]
    fn frontend_gather_adds_latency_and_area() {
        use crate::data::profile;
        use crate::embeddings::{EmbeddingStore, Placement, Strategy};
        let tech = TechParams::default();
        let m = map_genome(&autorac_best("criteo"), &tech, MapStyle::Smart).unwrap();
        let p = profile("criteo").unwrap();
        let store = EmbeddingStore::random(&p, 32, 1);
        let tiles = MemoryTileModel::new(&store, 16, &tech);
        let freqs = Placement::zipf_freqs(&store.cards, p.zipf_alpha);
        let placement = Placement::build(&freqs, 16, Strategy::AccessAware);
        let rows: Vec<usize> = (0..store.n_fields()).map(|j| store.global_row(j, 0)).collect();
        let gather = tiles.gather_cost(&rows, &placement);
        let fe = EmbeddingFrontend { tiles: &tiles, placement: &placement, gather };
        let with = simulate(&m, Some(&fe), &Workload::default());
        let without = simulate(&m, None, &Workload::default());
        assert!(with.latency_ns_mean > without.latency_ns_mean);
        assert!(with.mem_area_mm2 > 0.0 && without.mem_area_mm2 == 0.0);
        assert!(with.power_mw > without.power_mw);
    }
}
