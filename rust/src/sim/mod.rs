//! Behavioral simulator (S9): latency / throughput / energy / area of a
//! mapped design under a request workload.

pub mod simulator;

pub use simulator::{simulate, EmbeddingFrontend, SimReport, Workload};
