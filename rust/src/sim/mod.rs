//! Behavioral simulator (S9): latency / throughput / energy / area of a
//! mapped design under a request workload.
//!
//! Thread-safety contract: `simulate` is a pure function of its inputs
//! (the per-run RNG is constructed from `Workload::seed` internally), and
//! every type crossing it is `Send + Sync` — the parallel search engine
//! (`nas::parallel`, S20) calls it concurrently from its worker pool.
//! The audit below turns any regression (e.g. an `Rc` or raw pointer
//! slipping into `MappedModel`/`SimReport`) into a compile error.

pub mod simulator;

pub use simulator::{simulate, EmbeddingFrontend, SimReport, Workload};

// Compile-time Send/Sync audit of the simulate() boundary: the bound
// checks run at type-check time, so the crate stops compiling if one
// of these types grows a non-thread-safe field. Never called.
#[allow(dead_code)]
fn audit_simulate_boundary_is_send_sync() {
    fn check<T: Send + Sync>() {}
    check::<SimReport>();
    check::<Workload>();
    check::<crate::mapping::MappedModel>();
    check::<crate::mapping::MappedOp>();
    check::<crate::pim::TechParams>();
}
