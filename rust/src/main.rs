//! `autorac` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   search       run the evolutionary co-search (Algorithm 1, parallel engine)
//!   search-bench serial vs N-worker co-search wall-clock + cache hit-rate
//!   simulate    behavioral simulation of a genome on the PIM design
//!   serve       serve CTR requests from the AOT model artifact via PJRT
//!   serve-bench shard-aware serving bench, MockEngine or the native
//!               PimEngine crossbar backend (offline)
//!   xbar-bench  batched crossbar kernel vs per-vector reference:
//!               MVMs/s per batch size + in-run bit-identity parity
//!   fault-bench measured fault-rate→logloss curve vs the analytic
//!               NoiseModel penalty (EXPERIMENTS §SJ cross-validation)
//!   eval        rust-side accuracy eval of the served model (Table 2 check)
//!   datagen     inspect the synthetic dataset generator
//!   table2 | table3 | fig2 | fig5 | fig6   regenerate paper artifacts
//!   artifacts   list artifact registry

use autorac::coordinator::loadgen::{
    self, Arrival, CrashInjector, LoadGenConfig, LoadReport, Scenario,
    ScenarioOutcome, ScenarioSpec, SlowInjector,
};
use autorac::coordinator::net::{NetServer, NetServerConfig};
use autorac::coordinator::{
    AdmissionPolicy, BatcherConfig, Coordinator, CoordinatorConfig,
    InferenceEngine, MetricsSnapshot, MockEngine, PimEngine, PjrtEngine,
    Policy, Request, ServingStore, TailConfig,
};
use autorac::util::json_lazy;
use autorac::data::{make_batch, profile, Generator, Splits, DEFAULT_SEED};
use autorac::embeddings::{
    head_rows_per_table, EmbeddingStore, HotCacheConfig, HotRowCache, ShardMap,
    ShardPolicy, ShardedStore,
};
use autorac::mapping::{
    build_pim_net, build_pim_net_with, map_genome, MapStyle, NetScratch,
};
use autorac::nas::{autorac_best, Genome, ParallelSearch, SearchConfig, Surrogate};
use autorac::pim::{
    BatchedXbar, FaultSpec, MatI32, NoiseModel, PimConfig, ProgrammedXbar,
    TechParams, XbarActivity, XbarOptions, XbarScratch,
};
use autorac::util::json::Json;
use autorac::util::rng::{seed_from_name, Rng};
use autorac::runtime::atns::TensorFile;
use autorac::runtime::client::Runtime;
use autorac::sim::{simulate, Workload};
use autorac::util::cli::Args;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

fn main() -> autorac::Result<()> {
    let args = Args::parse_env();
    match args.subcommand.as_deref() {
        Some("search") => cmd_search(&args),
        Some("search-bench") => cmd_search_bench(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("serve") => cmd_serve(&args),
        Some("serve-bench") => cmd_serve_bench(&args),
        Some("xbar-bench") => cmd_xbar_bench(&args),
        Some("fault-bench") => cmd_fault_bench(&args),
        Some("eval") => cmd_eval(&args),
        Some("datagen") => cmd_datagen(&args),
        Some("table2") => {
            autorac::report::table2(&artifacts_dir(&args))?;
            args.finish()
        }
        Some("table3") => {
            autorac::report::table3(&args.str_or("dataset", "criteo"))?;
            args.finish()
        }
        Some("fig2") => {
            autorac::report::fig2(&artifacts_dir(&args))?;
            args.finish()
        }
        Some("fig5") => {
            let cfg = search_cfg(&args)?;
            let (_, best) = autorac::report::fig5(cfg)?;
            autorac::report::fig6(&best);
            args.finish()
        }
        Some("fig6") => {
            let g = match args.get("genome") {
                Some(p) => Genome::load(std::path::Path::new(&p.to_string()))?,
                None => autorac_best(&args.str_or("dataset", "criteo")),
            };
            autorac::report::fig6(&g);
            args.finish()
        }
        Some("artifacts") => {
            let rt = Runtime::open(&artifacts_dir(&args))?;
            println!("platform: {}", rt.platform());
            for name in rt.artifact_names() {
                let m = rt.meta(name).unwrap();
                println!("  {:<22} kind={:<10} batch={}", name, m.kind, m.batch);
            }
            args.finish()
        }
        Some(other) => autorac::bail!("unknown subcommand `{other}` (try --help)"),
        None => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "autorac — automated PIM accelerator design for recommender systems\n\
         usage: autorac <search|search-bench|simulate|serve|serve-bench|xbar-bench|fault-bench|eval|datagen|table2|table3|fig2|fig5|fig6|artifacts> [--opts]\n\
         common: --dataset criteo|avazu|kdd   --artifacts <dir>   --seed N\n\
         search: --generations N --population N --children N --out best.json\n\
                 --workers N (eval threads; 1 = serial) --pareto N (archive cap)\n\
                 --no-cache (disable the genome-keyed eval memo)\n\
         search-bench: --workers N --generations N --seed N --dataset D (default: the\n\
                 24-generation default-config smoke, serial vs N workers,\n\
                 plus a duplicate-heavy cache smoke)\n\
         serve:  --requests N --workers N --batch N --rps N\n\
         serve-bench: --workers N --shards N --policy round-robin|least-queued|shard-affinity\n\
                      --placement round-robin|balanced|hot --requests N --rps R (0=closed loop)\n\
                      --concurrency N --coverage F --queue-cap N (0=unbounded) --admission reject|shed\n\
                      --shed-after-us N --exec-us N (mock only) --batch N --d-emb N\n\
                      --cache-rows N (hot-row cache capacity; 0 = off; in-process\n\
                      runs also rerun cache-off for the p99 comparison)\n\
                      --oov-frac F (fraction of ids replaced by the -1 sentinel)\n\
                      --engine mock|pim (pim = real crossbar math on BatchedXbar banks)\n\
                      --threads N (kernel threads per pim worker; 0 = all cores)\n\
                      --json PATH (machine-readable report, e.g. BENCH_serving.json)\n\
                      --listen ADDR (serve over TCP, e.g. 127.0.0.1:0; loopback\n\
                      self-bench unless --hold keeps serving until killed)\n\
                      --connect ADDR (drive an external server; client stats only)\n\
                      --conns N (loadgen connections, default 4) --quick (CI-sized run)\n\
                      --scenario steady|flash-crowd|hot-key-storm|worker-crash|diurnal|slow-worker|brownout|cell-fault\n\
                      (failure/traffic matrix, in-process only; SLO verdict in report)\n\
                      --crash-worker K --crash-after-ms T --crash-after-batches N (0=use T)\n\
                      --surge F (flash-crowd multiplier) --storm-rows N (hot-key set)\n\
                      --slo-p99-ms B (p99 budget for the SLO verdict, default 250)\n\
                      --slow-worker K --slow-after-batches N --slow-ms T --slow-jitter-ms J\n\
                      (gray straggler for slow-worker/brownout: correct but T ms late)\n\
                      --deadline-us D (per-request deadline on the wire; 0 = none)\n\
                      --hedge (arm the tail stack outside gray scenarios)\n\
                      --hedge-after-ms T --hedge-budget F (hedge trigger age / max\n\
                      hedge fraction; slow-worker+brownout arm the stack themselves\n\
                      and rerun unhedged for the p99 comparison)\n\
                      --fault-rate F --fault-seed S --spare-tiles N (cell-fault:\n\
                      stuck-at cells injected at program time, ABFT detection +\n\
                      spare-tile repair; needs --engine pim)\n\
         xbar-bench: --k N --n N (weight shape) --quick (short CI timings)\n\
                      --threads N (tile-parallel kernel threads; 0 = all cores)\n\
                      --json PATH (machine-readable report, e.g. BENCH_xbar.json)\n\
                      (always runs the parity sweep: batched kernel vs per-vector\n\
                      reference at threads 1 AND N, bit-identical outputs +\n\
                      activity, fail-closed)\n\
         fault-bench: --batches N --batch B --d-emb N --seed S\n\
                      --json PATH (measured stuck-at fault-rate -> score\n\
                      corruption curve, ABFT/repair off, vs the analytic\n\
                      NoiseModel logloss penalty; EXPERIMENTS §SJ)\n\
         eval:   --n N (test records)"
    );
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

fn search_cfg(args: &Args) -> autorac::Result<SearchConfig> {
    // config file first, CLI overrides on top
    let base = autorac::config::Config::from_args(args)?
        .search
        .unwrap_or_default();
    Ok(SearchConfig {
        dataset: args.str_or("dataset", &base.dataset),
        generations: args.usize_or("generations", base.generations)?,
        population: args.usize_or("population", base.population)?,
        children_per_gen: args.usize_or("children", base.children_per_gen)?,
        mutations_per_child: args.usize_or("mutations", base.mutations_per_child)?,
        sample_size: args.usize_or("sample", base.sample_size)?,
        seed: args.u64_or("seed", base.seed)?,
        sim_requests: args.usize_or("sim-requests", base.sim_requests)?,
        lambdas: base.lambdas,
        workers: args.usize_or("workers", base.workers)?,
        pareto_capacity: args.usize_or("pareto", base.pareto_capacity)?,
        cache: base.cache && !args.flag("no-cache"),
    })
}

fn cmd_search(args: &Args) -> autorac::Result<()> {
    let cfg = search_cfg(args)?;
    let out = args.str_or("out", "artifacts/searched_best.json");
    args.finish()?;
    let t0 = Instant::now();
    let mut search = ParallelSearch::new(cfg, Surrogate::load_default())?;
    let best = search.run()?;
    let dt = t0.elapsed().as_secs_f64();
    let cs = search.cache_stats();
    println!(
        "search done in {dt:.1}s on {} worker(s): {} evaluations ({:.0} evals/s), best criterion {:.4}",
        search.cfg.workers.max(1),
        search.trace.evaluations,
        search.trace.evaluations as f64 / dt.max(1e-9),
        best.criterion
    );
    println!(
        "cache: hit-rate {:.1}% ({}/{} lookups, {} genomes memoized)",
        100.0 * cs.hit_rate(),
        cs.hits,
        cs.lookups(),
        search.cache_len()
    );
    println!(
        "Pareto archive: {} points (capacity {}), {} offers rejected",
        search.archive.len(),
        search.archive.capacity(),
        search.archive.rejected
    );
    if let Some(knee) = search.archive.knee() {
        println!(
            "knee point: criterion {:.4} (loss {:.4}, 1/thr {:.3e}, area {:.2} mm², power {:.0} mW)",
            knee.criterion,
            knee.objectives[0],
            knee.objectives[1],
            knee.objectives[2],
            knee.objectives[3]
        );
    }
    autorac::report::fig6(&best.genome);
    best.genome.save(std::path::Path::new(&out))?;
    println!("saved {out}");
    Ok(())
}

/// `search-bench`: serial vs N-worker wall-clock on the default-config
/// smoke, a bit-identity check between the two traces, and a
/// duplicate-heavy smoke that must produce cache hits (verify.sh gates
/// on its hit-rate line).
fn cmd_search_bench(args: &Args) -> autorac::Result<()> {
    let workers = args.usize_or("workers", 8)?;
    let generations = args.usize_or("generations", 24)?;
    let dataset = args.str_or("dataset", "criteo");
    let seed = args.u64_or("seed", SearchConfig::default().seed)?;
    args.finish()?;

    let cfg = SearchConfig {
        dataset,
        generations,
        seed,
        ..SearchConfig::default()
    };
    fn run(
        cfg: SearchConfig,
    ) -> autorac::Result<(f64, ParallelSearch, autorac::nas::Individual)> {
        let t0 = Instant::now();
        let mut s = ParallelSearch::new(cfg, Surrogate::load_default())?;
        let best = s.run()?;
        let dt = t0.elapsed().as_secs_f64();
        Ok((dt, s, best))
    }

    println!(
        "search-bench {}: {} generations × {} children, population {}",
        cfg.dataset, cfg.generations, cfg.children_per_gen, cfg.population
    );
    let (serial_s, serial, serial_best) =
        run(SearchConfig { workers: 1, ..cfg.clone() })?;
    println!(
        "  serial (1 worker):   {serial_s:6.2}s  {:.0} evals/s  best {:.4}",
        serial.trace.evaluations as f64 / serial_s.max(1e-9),
        serial_best.criterion
    );
    let (par_s, par, par_best) = run(SearchConfig { workers, ..cfg.clone() })?;
    let cs = par.cache_stats();
    println!(
        "  parallel ({workers} workers): {par_s:6.2}s  {:.0} evals/s  best {:.4}",
        par.trace.evaluations as f64 / par_s.max(1e-9),
        par_best.criterion
    );
    println!(
        "  speedup {:.2}x | cache hit-rate {:.1}% ({}/{} lookups)",
        serial_s / par_s.max(1e-9),
        100.0 * cs.hit_rate(),
        cs.hits,
        cs.lookups()
    );
    let identical = serial.trace.best_criterion.len() == par.trace.best_criterion.len()
        && serial
            .trace
            .best_criterion
            .iter()
            .zip(&par.trace.best_criterion)
            .all(|(a, b)| a.to_bits() == b.to_bits())
        && serial
            .trace
            .mean_criterion
            .iter()
            .zip(&par.trace.mean_criterion)
            .all(|(a, b)| a.to_bits() == b.to_bits())
        && serial_best.genome.hash() == par_best.genome.hash();
    println!("  parallel trace bit-identical to serial: {identical}");
    autorac::ensure!(identical, "parallel trace diverged from serial");

    // Duplicate-heavy smoke: one mutation per child revisits neighbours
    // constantly — the cache must land hits here or it is broken.
    let (smoke_s, smoke, _) = run(SearchConfig {
        workers,
        mutations_per_child: 1,
        ..cfg
    })?;
    let ss = smoke.cache_stats();
    println!(
        "  duplicate-heavy smoke: cache hit-rate {:.1}% ({}/{} lookups, \
         {} of {} evaluations simulated, {smoke_s:.2}s)",
        100.0 * ss.hit_rate(),
        ss.hits,
        ss.lookups(),
        smoke.sims_run(),
        smoke.trace.evaluations
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> autorac::Result<()> {
    let dataset = args.str_or("dataset", "criteo");
    let genome = match args.get("genome") {
        Some(p) => Genome::load(std::path::Path::new(&p.to_string()))?,
        None => autorac_best(&dataset),
    };
    let style = if args.flag("naive") {
        MapStyle::Naive
    } else {
        MapStyle::Smart
    };
    let n = args.usize_or("requests", 256)?;
    args.finish()?;
    let tech = TechParams::default();
    let mapped = map_genome(&genome, &tech, style)?;
    let report = simulate(
        &mapped,
        None,
        &Workload {
            n_requests: n,
            ..Workload::default()
        },
    );
    println!("design {}", report.design);
    println!("  latency    {:.2} µs (p99 {:.2} µs)", report.latency_ns_mean / 1e3, report.latency_ns_p99 / 1e3);
    println!("  throughput {:.0} inf/s", report.throughput_rps);
    println!("  energy     {:.1} nJ/inf", report.energy_pj_per_inf / 1e3);
    println!("  power      {:.2} W", report.power_mw / 1e3);
    println!("  area       {:.2} mm² ({} arrays, {} ops)", report.area_mm2, mapped.total_arrays, mapped.ops.len());
    println!("  setup      {:.1} µs / {:.1} µJ (crossbar programming)", mapped.setup_ns / 1e3, mapped.setup_pj / 1e6);
    Ok(())
}

fn cmd_serve(args: &Args) -> autorac::Result<()> {
    let dataset = args.str_or("dataset", "criteo");
    let dir = artifacts_dir(args);
    let n = args.usize_or("requests", 2000)?;
    let workers = args.usize_or("workers", 1)?;
    let batch = args.usize_or("batch", 32)?;
    let rps = args.f64_or("rps", f64::INFINITY)?;
    args.finish()?;
    autorac::ensure!(
        Runtime::pjrt_available(),
        "PJRT backend not linked in this offline build (stub runtime::xla) — \
         `serve` needs artifact execution"
    );

    let prof = profile(&dataset)?;
    let tf = TensorFile::read(&dir.join(format!("embeddings_{dataset}.bin")))?;
    let store = Arc::new(EmbeddingStore::from_atns(&tf)?);
    let (n_dense, n_sparse, d_emb) = (prof.n_dense, prof.n_sparse(), store.d_emb);
    let dir2 = dir.clone();
    let dataset2 = dataset.clone();
    let coord = Coordinator::start(
        CoordinatorConfig {
            n_workers: workers,
            ..Default::default()
        },
        store,
        move |_| {
            let rt = Runtime::open(&dir2)?;
            Ok(Box::new(PjrtEngine::new(
                rt, &dataset2, batch, n_dense, n_sparse, d_emb,
            )?))
        },
    )?;

    let mut gen = Generator::new(prof, DEFAULT_SEED);
    let (tx, rx) = mpsc::channel();
    let t0 = Instant::now();
    let gap = if rps.is_finite() { 1e9 / rps } else { 0.0 };
    let mut next_ns = 0f64;
    for id in 0..n {
        if gap > 0.0 {
            next_ns += gap;
            let now = t0.elapsed().as_nanos() as f64;
            if now < next_ns {
                std::thread::sleep(std::time::Duration::from_nanos(
                    (next_ns - now) as u64,
                ));
            }
        }
        let (dense, ids) = gen.features(id);
        coord.submit(Request::full(
            id as u64,
            dense,
            ids.iter().map(|&x| x as i32).collect(),
            tx.clone(),
        ))?;
    }
    drop(tx);
    let responses: Vec<_> = rx.iter().collect();
    let snap = coord.metrics.snapshot();
    coord.shutdown();
    autorac::ensure!(responses.len() == n, "lost responses: {}", responses.len());
    println!("served {n} requests on {workers} worker(s), artifact batch {batch}");
    println!(
        "  throughput {:.0} req/s | mean batch {:.1} | e2e p50 {:.0} µs p99 {:.0} µs | exec p50 {:.0} µs",
        snap.throughput_rps, snap.mean_batch, snap.e2e_p50_us, snap.e2e_p99_us, snap.exec_p50_us
    );
    let mean_prob: f64 =
        responses.iter().map(|r| r.prob as f64).sum::<f64>() / n as f64;
    println!("  mean p(click) {:.4}", mean_prob);
    Ok(())
}

/// Which compute backend serve-bench workers run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ServeEngine {
    /// sigmoid-of-means stand-in with a configurable `--exec-us` delay
    Mock,
    /// real crossbar math: `PimEngine` over `BatchedXbar` banks
    Pim,
}

/// Everything one serve-bench run needs (shared by the measured policy
/// and the round-robin baseline so the comparison is apples-to-apples).
#[derive(Clone)]
struct ServeBenchSetup {
    engine: ServeEngine,
    dataset: String,
    workers: usize,
    shards: usize,
    placement: ShardPolicy,
    n_requests: usize,
    arrival: Arrival,
    coverage: f64,
    queue_cap: usize,
    admission: AdmissionPolicy,
    shed_after: std::time::Duration,
    exec_delay: std::time::Duration,
    batch: usize,
    d_emb: usize,
    seed: u64,
    /// kernel worker threads per pim engine (mock ignores it)
    threads: usize,
    /// hot-row cache capacity in rows (0 = no cache tier)
    cache_rows: usize,
    /// fraction of ids the loadgen replaces with the `-1` OOV sentinel
    oov_frac: f64,
    /// traffic/failure scenario this run replays (S31)
    spec: ScenarioSpec,
    /// p99 budget the scenario SLO verdict is judged against, µs
    slo_p99_us: f64,
    /// per-request deadline the loadgen stamps on the wire, µs (0 = none)
    deadline_us: u64,
    /// gray-failure tail tolerance (S33): `Some` arms deadline
    /// admission, hedged dispatch, quarantine routing, and brownout
    tail: Option<TailConfig>,
}

/// Build the sharded store + coordinator for one serve-bench run
/// (shared by the in-process driver and the `--listen` socket server).
fn serve_bench_coordinator(
    s: &ServeBenchSetup,
    policy: Policy,
) -> autorac::Result<Coordinator> {
    let prof = profile(&s.dataset)?;
    // Cache-aware placement: rows resident in the hot cache are served
    // before any shard is consulted, so the HotReplicated pass charges
    // replicas only for each table's uncached remainder.
    let cached_rows = if s.cache_rows > 0 {
        head_rows_per_table(&prof.cards, prof.zipf_alpha, s.cache_rows)
    } else {
        Vec::new()
    };
    let map = ShardMap::build_cached(
        &prof.cards,
        prof.zipf_alpha,
        s.shards,
        s.placement,
        &cached_rows,
    );
    let store = Arc::new(ShardedStore::random(&prof, s.d_emb, s.seed, map));
    let serving = if s.cache_rows > 0 {
        let cache = HotRowCache::new(
            &store,
            prof.zipf_alpha,
            HotCacheConfig {
                capacity: s.cache_rows,
                prefetch: true,
            },
        );
        ServingStore::Cached(store, Arc::new(cache))
    } else {
        ServingStore::Sharded(store)
    };
    let (nd, nf, d_emb, batch) = (prof.n_dense, prof.n_sparse(), s.d_emb, s.batch);
    let delay = s.exec_delay;
    let engine = s.engine;
    let genome = autorac_best(&s.dataset);
    let seed = s.seed;
    let threads = s.threads;
    // worker-crash scenario: the victim's engine gets a CrashAfter fuse
    // (deadline anchored here, ≈ coordinator start); slow-worker and
    // brownout scenarios a SlowAfter gray fault; None otherwise
    let inj = CrashInjector::new(&s.spec);
    let slow = SlowInjector::new(&s.spec);
    // cell-fault scenario (S34): each worker's PIM banks are programmed
    // with seeded stuck-at faults drawn from an independent per-worker
    // substream, plus a spare-tile repair budget. `--fault-rate 0`
    // keeps the devices pristine (and the outputs bit-identical to a
    // plain build) while still exercising the ABFT verify path.
    let fault = (s.spec.scenario == Scenario::CellFault)
        .then(|| (s.spec.fault_rate, s.spec.fault_seed, s.spec.spare_tiles));
    let tail = s.tail.clone();
    Coordinator::start_with(
        CoordinatorConfig {
            n_workers: s.workers,
            policy,
            queue_cap: s.queue_cap,
            admission: s.admission,
            shed_after: s.shed_after,
            batcher: BatcherConfig {
                max_batch: batch,
                max_wait: std::time::Duration::ZERO,
            },
            tail,
        },
        serving,
        move |i| {
            let e: Box<dyn autorac::coordinator::InferenceEngine> = match engine
            {
                ServeEngine::Mock => {
                    let mut e = MockEngine::new(batch, nd, nf, d_emb);
                    e.delay = delay;
                    Box::new(e)
                }
                ServeEngine::Pim => {
                    let e = match fault {
                        Some((rate, fseed, spares)) => {
                            let opts = XbarOptions {
                                spare_tiles: spares,
                                fault: Some(FaultSpec::cells(
                                    rate,
                                    seed_from_name(
                                        fseed,
                                        &format!("worker/{i}"),
                                    ),
                                )),
                                ..XbarOptions::default()
                            };
                            PimEngine::new_with(
                                &genome, batch, nd, nf, d_emb, seed, &opts,
                            )?
                        }
                        None => {
                            PimEngine::new(&genome, batch, nd, nf, d_emb, seed)?
                        }
                    };
                    Box::new(e.with_threads(threads))
                }
            };
            let e = match &inj {
                Some(inj) => inj.arm(i, e),
                None => e,
            };
            Ok(match &slow {
                Some(slow) => slow.arm(i, e),
                None => e,
            })
        },
    )
}

fn serve_bench_loadcfg(s: &ServeBenchSetup) -> LoadGenConfig {
    LoadGenConfig {
        n_requests: s.n_requests,
        arrival: s.arrival,
        seed: s.seed,
        coverage: s.coverage,
        oov_frac: s.oov_frac,
        deadline_us: s.deadline_us,
    }
}

fn serve_bench_run(
    s: &ServeBenchSetup,
    policy: Policy,
) -> autorac::Result<(MetricsSnapshot, ScenarioOutcome)> {
    let prof = profile(&s.dataset)?;
    let coord = serve_bench_coordinator(s, policy)?;
    let out =
        loadgen::run_scenario(&coord, &prof, &serve_bench_loadcfg(s), &s.spec)?;
    // A dying worker's guard books its losses in the same instant it
    // releases the last reply sender, but give the ledger a bounded
    // beat anyway so the SLO verdict never races a straggling Drop.
    let t0 = Instant::now();
    let snap = loop {
        let snap = coord.metrics.snapshot();
        if snap.ledger_ok()
            || t0.elapsed() > std::time::Duration::from_secs(2)
        {
            break snap;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    };
    coord.shutdown();
    Ok((snap, out))
}

/// ns/request for the tree and lazy parsers over the deterministic wire
/// corpus (hot fields + a realistic cold `ctx` payload the scorer
/// ignores — exactly where lazy extraction pays).
fn parse_microbench(
    s: &ServeBenchSetup,
) -> autorac::Result<(f64, f64)> {
    let prof = profile(&s.dataset)?;
    let mut cfg = serve_bench_loadcfg(s);
    cfg.n_requests = cfg.n_requests.clamp(1, 512);
    let corpus = loadgen::wire_corpus(&prof, &cfg, true)?;
    let lines: Vec<&[u8]> =
        corpus.iter().map(|l| l.trim_end().as_bytes()).collect();
    let budget = std::time::Duration::from_millis(250);
    let per = |f: &dyn Fn(&[u8])| -> f64 {
        let t = time_per_call(budget, || {
            for line in &lines {
                f(line);
            }
        });
        t / lines.len() as f64 * 1e9
    };
    let tree_ns = per(&|b| {
        let _ = std::hint::black_box(json_lazy::parse_request_tree(b));
    });
    let lazy_ns = per(&|b| {
        let _ = std::hint::black_box(json_lazy::parse_request(b));
    });
    Ok((tree_ns, lazy_ns))
}

fn cmd_serve_bench(args: &Args) -> autorac::Result<()> {
    let policy = Policy::parse(&args.str_or("policy", "shard-affinity"))?;
    let workers = args.usize_or("workers", 4)?;
    let rps = args.f64_or("rps", 0.0)?;
    let queue_cap = args.usize_or("queue-cap", 0)?;
    // consume unconditionally so `--concurrency` with `--rps` still
    // passes finish() (it is simply unused in open loop)
    let concurrency = args.usize_or("concurrency", 64)?;
    let admission = match args.str_or("admission", "reject").as_str() {
        "reject" => AdmissionPolicy::RejectNew,
        "shed" => AdmissionPolicy::ShedStale,
        other => autorac::bail!("unknown admission `{other}` (reject|shed)"),
    };
    let engine = match args.str_or("engine", "mock").as_str() {
        "mock" => ServeEngine::Mock,
        "pim" => ServeEngine::Pim,
        other => autorac::bail!("unknown engine `{other}` (mock|pim)"),
    };
    // consumed for both engines so mock runs don't fail finish(); 0 = all cores
    let threads = match args.usize_or("threads", 1)? {
        0 => host_threads(),
        t => t,
    };
    let cache_rows = args.usize_or("cache-rows", 0)?;
    let oov_frac = args.f64_or("oov-frac", 0.0)?;
    autorac::ensure!(
        (0.0..=1.0).contains(&oov_frac),
        "--oov-frac must be in [0, 1], got {oov_frac}"
    );
    // Failure-scenario matrix (S31). All knobs are consumed
    // unconditionally so finish() passes whatever scenario runs.
    let scenario = Scenario::parse(&args.str_or("scenario", "steady"))?;
    let mut spec = ScenarioSpec::new(scenario);
    spec.surge = args.f64_or("surge", spec.surge)?;
    spec.storm_rows = args.usize_or("storm-rows", spec.storm_rows)?;
    spec.crash_worker = args.usize_or("crash-worker", spec.crash_worker)?;
    spec.crash_after = std::time::Duration::from_millis(
        args.u64_or("crash-after-ms", 60)?,
    );
    spec.crash_after_batches = match args.usize_or("crash-after-batches", 0)? {
        0 => None, // 0 = use the wall-clock fuse
        n => Some(n),
    };
    // Gray-failure knobs (S33) — likewise consumed unconditionally.
    spec.slow_worker = args.usize_or("slow-worker", spec.slow_worker)?;
    spec.slow_after_batches =
        args.usize_or("slow-after-batches", spec.slow_after_batches)?;
    spec.slow_delay =
        std::time::Duration::from_millis(args.u64_or("slow-ms", 20)?);
    spec.slow_jitter =
        std::time::Duration::from_millis(args.u64_or("slow-jitter-ms", 2)?);
    // Device-fault knobs (S34) — likewise consumed unconditionally.
    spec.fault_rate = args.f64_or("fault-rate", spec.fault_rate)?;
    spec.fault_seed = args.u64_or("fault-seed", spec.fault_seed)?;
    spec.spare_tiles = args.usize_or("spare-tiles", spec.spare_tiles)?;
    autorac::ensure!(
        (0.0..=1.0).contains(&spec.fault_rate),
        "--fault-rate must be in [0, 1], got {}",
        spec.fault_rate
    );
    let deadline_us = args.u64_or("deadline-us", 0)?;
    let hedge_after = std::time::Duration::from_millis(
        args.u64_or("hedge-after-ms", 5)?,
    );
    let hedge_budget = args.f64_or("hedge-budget", 0.1)?;
    autorac::ensure!(
        (0.0..=1.0).contains(&hedge_budget),
        "--hedge-budget must be in [0, 1], got {hedge_budget}"
    );
    // the tail stack arms automatically for the gray-failure scenarios;
    // --hedge opts any other shape in (defaults stay bit-identical off)
    let tail_on = args.flag("hedge")
        || matches!(scenario, Scenario::SlowWorker | Scenario::Brownout);
    let tail = tail_on.then(|| TailConfig {
        hedge_after,
        hedge_budget,
        ..Default::default()
    });
    let slo_p99_us = args.f64_or("slo-p99-ms", 250.0)? * 1e3;
    if scenario == Scenario::WorkerCrash {
        autorac::ensure!(
            spec.crash_worker < workers,
            "--crash-worker {} out of range (workers {})",
            spec.crash_worker,
            workers
        );
        autorac::ensure!(
            workers >= 2,
            "worker-crash needs >= 2 workers to have a survivor"
        );
    }
    if matches!(scenario, Scenario::SlowWorker | Scenario::Brownout) {
        autorac::ensure!(
            spec.slow_worker < workers,
            "--slow-worker {} out of range (workers {})",
            spec.slow_worker,
            workers
        );
        autorac::ensure!(
            workers >= 2,
            "{} needs >= 2 workers so hedges have somewhere to go",
            scenario.name()
        );
    }
    if scenario == Scenario::CellFault {
        autorac::ensure!(
            matches!(engine, ServeEngine::Pim),
            "cell-fault injects stuck-at faults into BatchedXbar weight \
             banks — it needs --engine pim"
        );
    }
    let json_path = args.get("json").map(str::to_string);
    // Socket-mode flags (S28) — consumed unconditionally so finish()
    // passes whether or not a transport was picked.
    let listen = args.get("listen").map(str::to_string);
    let connect = args.get("connect").map(str::to_string);
    let conns = args.usize_or("conns", 4)?;
    let quick = args.flag("quick");
    let hold = args.flag("hold");
    let setup = ServeBenchSetup {
        engine,
        dataset: args.str_or("dataset", "criteo"),
        workers,
        shards: args.usize_or("shards", workers)?,
        placement: ShardPolicy::parse(&args.str_or("placement", "hot"))?,
        n_requests: args.usize_or("requests", if quick { 400 } else { 4000 })?,
        arrival: if rps > 0.0 {
            Arrival::OpenLoop { rps }
        } else {
            Arrival::ClosedLoop { concurrency }
        },
        coverage: args.f64_or("coverage", 0.35)?,
        queue_cap: if queue_cap == 0 { usize::MAX } else { queue_cap },
        admission,
        shed_after: std::time::Duration::from_micros(
            args.u64_or("shed-after-us", 2000)?,
        ),
        exec_delay: std::time::Duration::from_micros(args.u64_or("exec-us", 30)?),
        batch: args.usize_or("batch", 32)?,
        d_emb: args.usize_or("d-emb", 16)?,
        seed: args.u64_or("seed", 7)?,
        threads,
        cache_rows,
        oov_frac,
        spec,
        slo_p99_us,
        deadline_us,
        tail,
    };
    args.finish()?;
    if listen.is_some() && connect.is_some() {
        autorac::bail!("--listen and --connect are mutually exclusive");
    }
    if (listen.is_some() || connect.is_some()) && scenario != Scenario::Steady {
        autorac::bail!(
            "--scenario {} needs the in-process driver \
             (drop --listen/--connect)",
            scenario.name()
        );
    }

    // Client-only mode: drive an external server over TCP and report
    // wire-level stats (the server's ledger is not visible from here).
    if let Some(addr_s) = connect {
        let addr = resolve_addr(&addr_s)?;
        let prof = profile(&setup.dataset)?;
        println!(
            "serve-bench {} -> {addr} ({conns} conns, {:?})",
            setup.dataset, setup.arrival
        );
        let (rep, wire) =
            loadgen::run_socket(&addr, &prof, &serve_bench_loadcfg(&setup), conns)?;
        print_wire_stats(&rep, &wire, conns);
        if let Some(path) = json_path {
            let report = Json::from_pairs(vec![
                ("bench", Json::Str("serving".into())),
                ("schema_version", Json::Num(2.0)),
                ("transport", Json::Str("socket-client".into())),
                ("dataset", Json::Str(setup.dataset.clone())),
                ("conns", Json::Num(conns as f64)),
                ("requests", Json::Num(setup.n_requests as f64)),
                ("sent", Json::Num(rep.sent as f64)),
                ("accepted", Json::Num(rep.accepted as f64)),
                ("rejected", Json::Num(rep.rejected as f64)),
                ("completed", Json::Num(rep.completed as f64)),
                ("expired", Json::Num(rep.expired as f64)),
                ("wire_p50_us", Json::Num(wire.wire_p50_us)),
                ("wire_p99_us", Json::Num(wire.wire_p99_us)),
                ("client_rps", Json::Num(wire.client_rps)),
            ]);
            report.write_file(std::path::Path::new(&path))?;
            println!("wrote {path}");
        }
        return Ok(());
    }

    let engine_desc = match setup.engine {
        ServeEngine::Mock => {
            format!("MockEngine {} µs/batch", setup.exec_delay.as_micros())
        }
        ServeEngine::Pim => format!(
            "PimEngine (BatchedXbar banks of genome {}, {} kernel thread(s))",
            autorac_best(&setup.dataset).name,
            setup.threads
        ),
    };
    println!(
        "serve-bench {}: {} workers / {} shards ({:?}), policy {:?}, \
         {engine_desc}, {:?}",
        setup.dataset,
        setup.workers,
        setup.shards,
        setup.placement,
        policy,
        setup.arrival,
    );
    // Socket server mode: same stack behind the TCP front end (S28),
    // driven over real loopback sockets; the round-robin baseline rerun
    // is skipped (wire timing, not placement, is the subject here).
    if let Some(listen_addr) = listen {
        let coord = serve_bench_coordinator(&setup, policy)?;
        let server =
            NetServer::start(&listen_addr, coord, NetServerConfig::default())?;
        let addr = server.local_addr();
        println!("  listening on {addr}");
        if hold {
            println!("  --hold: serving until killed");
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        let prof = profile(&setup.dataset)?;
        let (rep, wire) =
            loadgen::run_socket(&addr, &prof, &serve_bench_loadcfg(&setup), conns)?;
        let snap = server.metrics();
        let stats = Arc::clone(&server.stats);
        server.shutdown();
        print_serve_bench(&snap, &rep);
        print_wire_stats(&rep, &wire, conns);
        let (tree_ns, lazy_ns) = parse_microbench(&setup)?;
        let speedup = tree_ns / lazy_ns.max(1e-9);
        println!(
            "  parse: tree {tree_ns:.0} ns/req | lazy {lazy_ns:.0} ns/req | \
             lazy {speedup:.1}x faster"
        );
        if let Some(path) = json_path {
            let ld = |v: &std::sync::atomic::AtomicU64| {
                Json::Num(v.load(std::sync::atomic::Ordering::Relaxed) as f64)
            };
            let mut pairs = serve_bench_report(&setup, policy, &snap, &rep);
            pairs.extend(vec![
                ("transport", Json::Str("socket".into())),
                ("conns", Json::Num(conns as f64)),
                ("wire_p50_us", Json::Num(wire.wire_p50_us)),
                ("wire_p99_us", Json::Num(wire.wire_p99_us)),
                ("client_rps", Json::Num(wire.client_rps)),
                ("frames_ok", ld(&stats.frames_ok)),
                ("frames_bad", ld(&stats.frames_bad)),
                ("lazy_frames", ld(&stats.lazy_frames)),
                ("tree_frames", ld(&stats.tree_frames)),
                ("conns_idle_closed", ld(&stats.conns_idle_closed)),
                ("tree_parse_ns", Json::Num(tree_ns)),
                ("lazy_parse_ns", Json::Num(lazy_ns)),
                ("lazy_speedup", Json::Num(speedup)),
            ]);
            let report = Json::from_pairs(pairs);
            report.write_file(std::path::Path::new(&path))?;
            println!("wrote {path}");
        }
        return Ok(());
    }

    let (snap, out) = serve_bench_run(&setup, policy)?;
    let rep = out.report.clone();
    print_serve_bench(&snap, &rep);
    print_scenario_slo(&setup, &snap, &out);
    // Gray-failure twin run (S33): replay the byte-identical schedule
    // with the tail stack off, so the hedged-vs-unhedged p99 comparison
    // isolates what hedging + quarantine buy against the same straggler.
    let tail_cmp = if matches!(
        setup.spec.scenario,
        Scenario::SlowWorker | Scenario::Brownout
    ) && setup.tail.is_some()
    {
        let off = ServeBenchSetup {
            tail: None,
            ..setup.clone()
        };
        let (base, _) = serve_bench_run(&off, policy)?;
        // The straggler's injected delay dwarfs normal service time, so
        // a real hedging win clears the 0.9 factor with a wide margin;
        // brownout is judged on the ledger alone (it trades fidelity
        // for latency, so a p99 win is the mechanism, not the verdict).
        let p99_win = snap.e2e_p99_us < base.e2e_p99_us * 0.9;
        let verdict = match setup.spec.scenario {
            Scenario::SlowWorker => {
                snap.ledger_ok() && snap.hedges > 0 && p99_win
            }
            _ => snap.ledger_ok(),
        };
        println!(
            "  tail SLO: hedges {} ({} won, rate {:.1}%) | expired {} | \
             deadline_rejected {} | degraded_responses {} | p99 hedged \
             {:.0} µs vs unhedged {:.0} µs | verdict {}",
            snap.hedges,
            snap.hedges_won,
            snap.hedge_rate() * 100.0,
            snap.expired,
            snap.deadline_rejected,
            snap.degraded_responses,
            snap.e2e_p99_us,
            base.e2e_p99_us,
            if verdict { "PASS" } else { "FAIL" }
        );
        Some((base.e2e_p99_us, verdict))
    } else {
        None
    };
    // Cell-fault verdict (S34): besides the serving run's ledger and
    // degraded-row counters, probe repair fidelity directly — a twin
    // pair of engines (worker 0's exact fault stream vs a pristine
    // build) scored on identical deterministic inputs must agree to
    // the bit once spares have absorbed the injected faults.
    let fault_cmp = if setup.spec.scenario == Scenario::CellFault {
        let prof = profile(&setup.dataset)?;
        let genome = autorac_best(&setup.dataset);
        let (nd, nf) = (prof.n_dense, prof.n_sparse());
        let opts = XbarOptions {
            spare_tiles: setup.spec.spare_tiles,
            fault: Some(FaultSpec::cells(
                setup.spec.fault_rate,
                seed_from_name(setup.spec.fault_seed, "worker/0"),
            )),
            ..XbarOptions::default()
        };
        let mut faulty = PimEngine::new_with(
            &genome, setup.batch, nd, nf, setup.d_emb, setup.seed, &opts,
        )?
        .with_threads(setup.threads);
        let mut clean =
            PimEngine::new(&genome, setup.batch, nd, nf, setup.d_emb, setup.seed)?
                .with_threads(setup.threads);
        let b = setup.batch.clamp(1, 8);
        let mut rng = Rng::new(setup.seed ^ 0x5A34);
        let mut probe_identical = true;
        for _ in 0..4 {
            let dense: Vec<f32> =
                (0..b * nd).map(|_| rng.normal() as f32).collect();
            let sparse: Vec<f32> = (0..b * nf * setup.d_emb)
                .map(|_| (rng.normal() * 0.05) as f32)
                .collect();
            let pf = faulty.infer_batch(&dense, &sparse, b)?;
            let pc = clean.infer_batch(&dense, &sparse, b)?;
            probe_identical &=
                pf.iter().zip(&pc).all(|(a, c)| a.to_bits() == c.to_bits());
        }
        let pfc = faulty.take_fault_counts();
        let probe_ok = probe_identical && pfc.corrupt_rows == 0;
        let verdict =
            snap.ledger_ok() && snap.corrupted_responses == 0 && probe_ok;
        println!(
            "  fault SLO: rate {:.2e} seed {:#x} spares {} | tiles faulty {} \
             repaired {} | corrupted responses {} | repair probe {} (faulty \
             {} repaired {}) | ledger {} | verdict {}",
            setup.spec.fault_rate,
            setup.spec.fault_seed,
            setup.spec.spare_tiles,
            snap.tiles_faulty,
            snap.tiles_repaired,
            snap.corrupted_responses,
            if probe_ok { "bit-identical" } else { "DIVERGED" },
            pfc.tiles_faulty,
            pfc.tiles_repaired,
            if snap.ledger_ok() { "balanced" } else { "IMBALANCED" },
            if verdict { "PASS" } else { "FAIL" }
        );
        Some(verdict)
    } else {
        None
    };
    if let Some(path) = json_path {
        let (avail, post_avail, slo_ok) = scenario_slo(&setup, &snap, &out);
        let mut pairs = serve_bench_report(&setup, policy, &snap, &rep);
        pairs.extend(vec![
            ("availability", Json::Num(avail)),
            ("post_crash_sent", Json::Num(out.post_crash_sent as f64)),
            (
                "post_crash_completed",
                Json::Num(out.post_crash_completed as f64),
            ),
            ("post_crash_availability", Json::Num(post_avail)),
            ("slo_ok", Json::Bool(slo_ok)),
        ]);
        if let Some((unhedged_p99, verdict)) = tail_cmp {
            pairs.extend(vec![
                ("unhedged_p99_us", Json::Num(unhedged_p99)),
                ("tail_slo_ok", Json::Bool(verdict)),
            ]);
        }
        if let Some(verdict) = fault_cmp {
            pairs.extend(vec![
                ("fault_rate", Json::Num(setup.spec.fault_rate)),
                ("fault_seed", Json::Num(setup.spec.fault_seed as f64)),
                ("spare_tiles", Json::Num(setup.spec.spare_tiles as f64)),
                ("fault_slo_ok", Json::Bool(verdict)),
            ]);
        }
        let report = Json::from_pairs(pairs);
        report.write_file(std::path::Path::new(&path))?;
        println!("wrote {path}");
    }

    // Baseline reruns only make sense against the steady shape — a
    // scenario run's comparison target is its own SLO line above.
    let steady = setup.spec.scenario == Scenario::Steady;

    // Same traffic under round-robin — the cross-shard-gather baseline.
    if steady && policy != Policy::RoundRobin {
        let (base, _) = serve_bench_run(&setup, Policy::RoundRobin)?;
        println!(
            "baseline round-robin: cross-shard {} rows ({:.1}%) | \
             p50 {:.0} µs p99 {:.0} µs",
            base.remote_rows,
            base.cross_shard_frac() * 100.0,
            base.e2e_p50_us,
            base.e2e_p99_us
        );
        match (snap.remote_rows, base.remote_rows) {
            (0, 0) => println!(
                "no cross-shard gathers under either policy \
                 (single shard or fully replicated tables)"
            ),
            (0, b) => println!(
                "{policy:?} eliminated cross-shard gathers entirely \
                 (round-robin fetched {b} rows)"
            ),
            (a, b) if b >= a => println!(
                "{policy:?} cross-shard gathers {:.1}× lower than round-robin",
                b as f64 / a as f64
            ),
            (a, b) => println!(
                "WARNING: {policy:?} cross-shard gathers {:.1}× HIGHER than \
                 round-robin ({a} vs {b} rows)",
                a as f64 / b.max(1) as f64
            ),
        }
    }

    // Same traffic with the cache disabled — the p99 headline the cache
    // tier exists for (EXPERIMENTS.md §SG). Identical schedule by
    // construction: the loadgen is deterministic by seed and the cache
    // never changes what is gathered, only where it is read from.
    if steady && setup.cache_rows > 0 {
        let off = ServeBenchSetup {
            cache_rows: 0,
            ..setup.clone()
        };
        let (base, _) = serve_bench_run(&off, policy)?;
        println!(
            "baseline cache-off: p50 {:.0} µs p99 {:.0} µs | local {} rows | \
             cross-shard {} rows",
            base.e2e_p50_us, base.e2e_p99_us, base.local_rows, base.remote_rows
        );
        if snap.e2e_p99_us < base.e2e_p99_us {
            println!(
                "cache p99 win: {:.0} µs -> {:.0} µs ({:.2}x) at {} cached rows",
                base.e2e_p99_us,
                snap.e2e_p99_us,
                base.e2e_p99_us / snap.e2e_p99_us.max(1e-9),
                setup.cache_rows
            );
        } else {
            println!(
                "WARNING: cache did not improve p99 ({:.0} µs vs {:.0} µs \
                 cache-off) — capacity below the head set, or the run is \
                 too short/noisy to separate them",
                snap.e2e_p99_us, base.e2e_p99_us
            );
        }
    }
    Ok(())
}

/// The serve-bench JSON report fields shared by the in-process and
/// `--listen` transports (socket runs append wire/parse fields).
fn serve_bench_report(
    setup: &ServeBenchSetup,
    policy: Policy,
    snap: &MetricsSnapshot,
    rep: &LoadReport,
) -> Vec<(&'static str, Json)> {
    vec![
        ("bench", Json::Str("serving".into())),
        // bumped whenever a field is added/renamed so downstream readers
        // can fail fast instead of silently missing columns
        ("schema_version", Json::Num(3.0)),
        (
            "engine",
            Json::Str(match setup.engine {
                ServeEngine::Mock => "mock".into(),
                ServeEngine::Pim => "pim".into(),
            }),
        ),
        ("policy", Json::Str(format!("{policy:?}"))),
        ("dataset", Json::Str(setup.dataset.clone())),
        ("workers", Json::Num(setup.workers as f64)),
        ("shards", Json::Num(setup.shards as f64)),
        ("threads", Json::Num(setup.threads as f64)),
        ("batch", Json::Num(setup.batch as f64)),
        ("requests", Json::Num(setup.n_requests as f64)),
        ("throughput_rps", Json::Num(snap.throughput_rps)),
        ("mean_batch", Json::Num(snap.mean_batch)),
        ("e2e_p50_us", Json::Num(snap.e2e_p50_us)),
        ("e2e_p99_us", Json::Num(snap.e2e_p99_us)),
        ("queue_p99_us", Json::Num(snap.queue_p99_us)),
        ("exec_p50_us", Json::Num(snap.exec_p50_us)),
        ("sent", Json::Num(rep.sent as f64)),
        ("accepted", Json::Num(rep.accepted as f64)),
        ("rejected", Json::Num(snap.rejected as f64)),
        ("shed", Json::Num(snap.shed as f64)),
        ("failed", Json::Num(snap.failed as f64)),
        ("expired", Json::Num(snap.expired as f64)),
        ("deadline_rejected", Json::Num(snap.deadline_rejected as f64)),
        ("hedges", Json::Num(snap.hedges as f64)),
        ("hedges_won", Json::Num(snap.hedges_won as f64)),
        ("hedge_rate", Json::Num(snap.hedge_rate())),
        ("degraded_responses", Json::Num(snap.degraded_responses as f64)),
        ("degraded_rows", Json::Num(snap.degraded_rows as f64)),
        ("tiles_faulty", Json::Num(snap.tiles_faulty as f64)),
        ("tiles_repaired", Json::Num(snap.tiles_repaired as f64)),
        (
            "corrupted_responses",
            Json::Num(snap.corrupted_responses as f64),
        ),
        ("brownout_entries", Json::Num(snap.brownout_entries as f64)),
        ("local_rows", Json::Num(snap.local_rows as f64)),
        ("remote_rows", Json::Num(snap.remote_rows as f64)),
        ("cache_rows", Json::Num(setup.cache_rows as f64)),
        ("cache_hits", Json::Num(snap.cache_hits as f64)),
        ("cache_misses", Json::Num(snap.cache_misses as f64)),
        ("cache_hit_rate", Json::Num(snap.cache_hit_rate())),
        ("cache_evictions", Json::Num(snap.cache_evictions as f64)),
        ("coalesced_rows", Json::Num(snap.coalesced_rows as f64)),
        ("oob_ids", Json::Num(snap.oob_ids as f64)),
        ("scenario", Json::Str(setup.spec.scenario.name().into())),
        ("ledger_ok", Json::Bool(snap.ledger_ok())),
        ("live_workers", Json::Num(snap.live_workers() as f64)),
        ("slo_p99_budget_us", Json::Num(setup.slo_p99_us)),
    ]
}

/// Availability split + SLO verdict for one in-process scenario run.
/// The availability gate judges post-crash traffic when the probe
/// classified any (requests offered AFTER the crash was observable);
/// otherwise it falls back to overall availability.
fn scenario_slo(
    setup: &ServeBenchSetup,
    snap: &MetricsSnapshot,
    out: &ScenarioOutcome,
) -> (f64, f64, bool) {
    let avail = if out.report.accepted == 0 {
        1.0
    } else {
        out.report.completed as f64 / out.report.accepted as f64
    };
    let post_avail = if out.post_crash_sent == 0 {
        avail
    } else {
        out.post_crash_completed as f64 / out.post_crash_sent as f64
    };
    let slo_ok = snap.e2e_p99_us <= setup.slo_p99_us
        && snap.ledger_ok()
        && post_avail >= 0.99;
    (avail, post_avail, slo_ok)
}

fn print_scenario_slo(
    setup: &ServeBenchSetup,
    snap: &MetricsSnapshot,
    out: &ScenarioOutcome,
) {
    let (avail, post_avail, slo_ok) = scenario_slo(setup, snap, out);
    println!(
        "  scenario {}: availability {:.2}% | post-crash {:.2}% ({}/{}) | \
         ledger {} | live workers {} | p99 {:.0} µs vs budget {:.0} µs | \
         SLO {}",
        setup.spec.scenario.name(),
        avail * 100.0,
        post_avail * 100.0,
        out.post_crash_completed,
        out.post_crash_sent,
        if snap.ledger_ok() { "balanced" } else { "IMBALANCED" },
        snap.live_workers(),
        snap.e2e_p99_us,
        setup.slo_p99_us,
        if slo_ok { "PASS" } else { "FAIL" }
    );
}

/// Resolve `host:port` to a socket address (first resolution wins).
fn resolve_addr(s: &str) -> autorac::Result<std::net::SocketAddr> {
    use std::net::ToSocketAddrs;
    s.to_socket_addrs()
        .map_err(|e| autorac::err!("resolving `{s}`: {e}"))?
        .next()
        .ok_or_else(|| autorac::err!("`{s}` resolved to no address"))
}

fn print_wire_stats(
    rep: &LoadReport,
    wire: &autorac::coordinator::WireStats,
    conns: usize,
) {
    println!(
        "  wire ({conns} conns): completed {} | e2e p50 {:.0} µs  \
         p99 {:.0} µs | {:.0} req/s over {:.2} s",
        rep.completed,
        wire.wire_p50_us,
        wire.wire_p99_us,
        wire.client_rps,
        wire.elapsed_s
    );
}

fn print_serve_bench(snap: &MetricsSnapshot, rep: &LoadReport) {
    println!(
        "  sent {} | accepted {} | rejected {} | shed {} | failed {} | \
         expired {} | lost {} | shed-rate {:.1}%",
        rep.sent,
        rep.accepted,
        rep.rejected,
        snap.shed,
        snap.failed,
        snap.expired,
        rep.lost,
        snap.shed_rate() * 100.0
    );
    println!(
        "  throughput {:.0} req/s | mean batch {:.1} | batches {}",
        snap.throughput_rps, snap.mean_batch, snap.batches
    );
    println!(
        "  latency p50 {:.0} µs  p99 {:.0} µs | queue p99 {:.0} µs | \
         exec p50 {:.0} µs",
        snap.e2e_p50_us, snap.e2e_p99_us, snap.queue_p99_us, snap.exec_p50_us
    );
    println!(
        "  gathers: local {} rows | cross-shard {} rows ({:.1}%) | \
         coalesced {} | oob ids {}",
        snap.local_rows,
        snap.remote_rows,
        snap.cross_shard_frac() * 100.0,
        snap.coalesced_rows,
        snap.oob_ids
    );
    // printed only when the cache saw traffic, so verify.sh's grep for
    // this line is fail-closed: a silently-disabled cache breaks CI
    if snap.cache_hits + snap.cache_misses > 0 {
        println!(
            "  cache: hit-rate {:.1}% ({}/{} lookups) | evictions {}",
            snap.cache_hit_rate() * 100.0,
            snap.cache_hits,
            snap.cache_hits + snap.cache_misses,
            snap.cache_evictions
        );
    }
}

/// Wall-clock seconds per call of `f` (one warmup call, then as many
/// calls as fit the budget). Single-threaded by construction.
fn time_per_call<F: FnMut()>(budget: std::time::Duration, mut f: F) -> f64 {
    f();
    let t0 = Instant::now();
    let mut calls = 0u64;
    while t0.elapsed() < budget {
        f();
        calls += 1;
    }
    t0.elapsed().as_secs_f64() / calls.max(1) as f64
}

/// Random weights spanning the full `w_bits` range of `cfg`.
fn random_weights(rng: &mut Rng, rows: usize, cols: usize, cfg: &PimConfig) -> MatI32 {
    let wmax = (1i32 << (cfg.w_bits - 1)) - 1;
    let mut wq = MatI32::zeros(rows, cols);
    for r in 0..rows {
        for c in 0..cols {
            wq.set(r, c, rng.below((2 * wmax + 1) as u64) as i32 - wmax);
        }
    }
    wq
}

/// Outputs + activity of the per-vector reference over a batch.
fn reference_mvm(
    xbar: &ProgrammedXbar,
    xs: &[i32],
    b: usize,
) -> (Vec<i64>, XbarActivity) {
    let mut act = XbarActivity::default();
    let mut out = Vec::with_capacity(b * xbar.n);
    for j in 0..b {
        out.extend(xbar.mvm_raw(&xs[j * xbar.k..(j + 1) * xbar.k], &mut act));
    }
    (out, act)
}

/// Worker threads to use when `--threads 0` (= all cores) is asked for.
/// One canonical core-count helper — `SearchConfig::all_cores` — serves
/// the search engine, the benches, and the kernel CLI alike.
fn host_threads() -> usize {
    SearchConfig::all_cores()
}

/// One timed xbar-bench case: parity-check the measured inputs (at every
/// thread count in `thread_grid`), then report reference vs batched
/// MVMs/s per thread count. Returns `(reference_mvms, batched_mvms)`
/// with `batched_mvms[i]` aligned to `thread_grid[i]`; every case is
/// also appended to `cases` for the `--json` report.
#[allow(clippy::too_many_arguments)]
fn xbar_time_case(
    label: &str,
    bx: &BatchedXbar,
    refx: &ProgrammedXbar,
    b: usize,
    thread_grid: &[usize],
    budget: std::time::Duration,
    rng: &mut Rng,
    cases: &mut Vec<Json>,
) -> autorac::Result<(f64, Vec<f64>)> {
    let cfg = bx.cfg;
    let xs: Vec<i32> = (0..b * bx.k)
        .map(|_| rng.below(1 << cfg.x_bits) as i32)
        .collect();
    let (want, want_act) = reference_mvm(refx, &xs, b);
    let mut act = XbarActivity::default();
    let ref_s = time_per_call(budget, || {
        for j in 0..b {
            std::hint::black_box(
                refx.mvm_raw(&xs[j * bx.k..(j + 1) * bx.k], &mut act),
            );
        }
    });
    let ref_mvms = b as f64 / ref_s;
    let mut bat_mvms = Vec::with_capacity(thread_grid.len());
    for &t in thread_grid {
        let mut out = vec![0i64; b * bx.n];
        let mut scratch = XbarScratch::with_threads(t);
        // bit-identity on the measured inputs, every run, per thread count
        bx.mvm_batch(&xs, b, &mut out, &mut scratch);
        autorac::ensure!(out == want, "{label}: output mismatch b={b} threads={t}");
        autorac::ensure!(
            scratch.activity == want_act,
            "{label}: activity mismatch b={b} threads={t}"
        );
        let bat_s = time_per_call(budget, || {
            bx.mvm_batch(&xs, b, &mut out, &mut scratch);
            std::hint::black_box(&out);
        });
        let mvms = b as f64 / bat_s;
        println!(
            "  {label} b={b:<3} threads={t:<2} reference {ref_mvms:>10.0} \
             MVM/s   batched {mvms:>10.0} MVM/s   speedup {:.2}x",
            mvms / ref_mvms
        );
        cases.push(Json::from_pairs(vec![
            ("case", Json::Str(label.trim().to_string())),
            ("rows", Json::Num(cfg.xbar as f64)),
            ("batch", Json::Num(b as f64)),
            ("threads", Json::Num(t as f64)),
            ("reference_mvms_per_s", Json::Num(ref_mvms)),
            ("batched_mvms_per_s", Json::Num(mvms)),
            ("speedup_vs_reference", Json::Num(mvms / ref_mvms)),
        ]));
        bat_mvms.push(mvms);
    }
    Ok((ref_mvms, bat_mvms))
}

/// `xbar-bench`: the batched multi-word bit-plane-packed kernel vs the
/// per-vector functional reference — a parity sweep over every feasible
/// PIM config (plus lossy-ADC and wide-tile configs) at kernel threads 1
/// AND N, then MVMs/s at b ∈ {1, 8, 32} × threads {1, N} with in-run
/// bit-identity `ensure!`s, and a rows=128 wide-tile case (the geometry
/// the deleted i64 fallback used to catch). `verify.sh` runs this with
/// `--quick --threads 4` and greps the `parity: OK` line (fail-closed).
/// `--json PATH` additionally writes the machine-readable report.
fn cmd_xbar_bench(args: &Args) -> autorac::Result<()> {
    let k = args.usize_or("k", 256)?;
    let n = args.usize_or("n", 128)?;
    let quick = args.flag("quick");
    let threads = match args.usize_or("threads", 0)? {
        0 => host_threads(),
        t => t,
    };
    let json_path = args.get("json").map(str::to_string);
    args.finish()?;
    let budget = std::time::Duration::from_millis(if quick { 40 } else { 300 });
    let thread_grid: Vec<usize> = if threads > 1 { vec![1, threads] } else { vec![1] };

    // ---- parity sweep: every feasible config + lossy + wide tiles -----
    let mut sweep = PimConfig::enumerate_feasible();
    let n_feasible = sweep.len();
    sweep.push(PimConfig {
        xbar: 64,
        dac_bits: 2,
        cell_bits: 2,
        adc_bits: 8,
        ..Default::default()
    }); // infeasible: lossy ADC
    sweep.push(PimConfig {
        xbar: 128,
        dac_bits: 1,
        cell_bits: 1,
        adc_bits: 8,
        ..Default::default()
    }); // wide tile (2 words/column), lossless
    sweep.push(PimConfig {
        xbar: 128,
        dac_bits: 1,
        cell_bits: 2,
        adc_bits: 8,
        ..Default::default()
    }); // wide tile, lossy
    sweep.push(PimConfig {
        xbar: 192,
        dac_bits: 1,
        cell_bits: 1,
        adc_bits: 8,
        ..Default::default()
    }); // 3 words/column (192·1·1 = 192 ≤ 255: lossless)
    let mut rng = Rng::new(0xBA7C);
    for (ci, cfg) in sweep.iter().enumerate() {
        for w_bits in [4usize, 8] {
            let cfg = cfg.with_wbits(w_bits);
            let wq = random_weights(&mut rng, cfg.xbar + 3, 9, &cfg); // K-padding edge
            let refx = ProgrammedXbar::program(&wq, cfg);
            let bx = BatchedXbar::program(&wq, cfg);
            autorac::ensure!(
                bx.offset_correction() == refx.offset_correction(),
                "offset-correction mismatch on {cfg:?}"
            );
            for b in [1usize, 3, 8] {
                let xs: Vec<i32> = (0..b * bx.k)
                    .map(|_| rng.below(1 << cfg.x_bits) as i32)
                    .collect();
                let (want, want_act) = reference_mvm(&refx, &xs, b);
                for &t in &thread_grid {
                    let mut out = vec![0i64; b * bx.n];
                    let mut scratch = XbarScratch::with_threads(t);
                    bx.mvm_batch(&xs, b, &mut out, &mut scratch);
                    autorac::ensure!(
                        out == want,
                        "output mismatch: config {ci} {cfg:?} b={b} threads={t}"
                    );
                    autorac::ensure!(
                        scratch.activity == want_act,
                        "activity mismatch: config {ci} {cfg:?} b={b} threads={t}"
                    );
                    // ABFT zero-false-positive gate (S34): pristine
                    // devices must never trip the checksum verify —
                    // on lossless configs it runs and stays silent,
                    // on lossy ones it is gated off entirely
                    autorac::ensure!(
                        scratch.flagged.is_empty()
                            && scratch.activity.faulty_tiles == 0,
                        "ABFT false positive on clean hardware: config \
                         {ci} {cfg:?} b={b} threads={t}"
                    );
                }
            }
        }
    }
    println!(
        "parity: OK — {n_feasible} feasible + {} lossy/wide configs × \
         w_bits {{4,8}} × b {{1,3,8}} × threads {{1,{threads}}}, outputs \
         and activity bit-identical, zero ABFT false positives",
        sweep.len() - n_feasible
    );

    let mut cases: Vec<Json> = Vec::new();

    // ---- throughput, default config (64-row tiles) --------------------
    let cfg = PimConfig::default();
    let wq = random_weights(&mut rng, k, n, &cfg);
    let refx = ProgrammedXbar::program(&wq, cfg);
    let bx = BatchedXbar::program(&wq, cfg);
    println!(
        "xbar-bench: default config {}/{}/{}/{} (lossless ADC), W {k}×{n}, \
         x_bits {}, host threads {}",
        cfg.xbar, cfg.dac_bits, cfg.cell_bits, cfg.adc_bits, cfg.x_bits,
        host_threads()
    );
    let mut pack_speedup_b32 = 0.0;
    let mut thread_speedup_b32 = 1.0;
    for b in [1usize, 8, 32] {
        let (ref_mvms, mvms) = xbar_time_case(
            "rows=64 ", &bx, &refx, b, &thread_grid, budget, &mut rng, &mut cases,
        )?;
        if b == 32 {
            pack_speedup_b32 = mvms[0] / ref_mvms;
            if mvms.len() > 1 {
                thread_speedup_b32 = mvms[mvms.len() - 1] / mvms[0];
            }
        }
    }
    println!(
        "  b=32: packed speedup {pack_speedup_b32:.2}x vs reference \
         (target >= 5x), {threads}-thread speedup {thread_speedup_b32:.2}x \
         vs 1 thread (target >= 2x on a >= 4-core host)"
    );

    // ---- wide-tile case: rows=128, the old blocked fallback's geometry.
    // The per-vector reference is the surviving scalar-i64 PROXY for
    // that fallback: both pay the same O(xbar) MAC per (plane, sign,
    // column) that packing collapses to popcounts. They differ at the
    // margins in both directions (the fallback amortized input-chunk
    // extraction over the batch; the reference skips zero chunks, which
    // the fallback never did), so treat the ratio as the acceptance
    // indicator, not a bit-exact before/after of deleted code.
    let wcfg = PimConfig {
        xbar: 128,
        dac_bits: 1,
        cell_bits: 1,
        adc_bits: 8,
        ..Default::default()
    };
    let wwq = random_weights(&mut rng, k.max(2 * wcfg.xbar), n, &wcfg);
    let wrefx = ProgrammedXbar::program(&wwq, wcfg);
    let wbx = BatchedXbar::program(&wwq, wcfg);
    let (wide_ref, wide) = xbar_time_case(
        "rows=128", &wbx, &wrefx, 32, &thread_grid, budget, &mut rng, &mut cases,
    )?;
    let wide_speedup = wide[0] / wide_ref;
    println!(
        "  rows=128 b=32: packed speedup {wide_speedup:.2}x vs the scalar \
         per-vector path (proxy for the old blocked fallback; target >= 3x)"
    );

    // ---- ABFT overhead: checksum verify on vs off, default config.
    // The checksum column rides the packed layout, so the cost is one
    // extra ~chk_planes-wide unit per tile plus the per-tile compare —
    // the acceptance bar is <= 10% of MVMs/s at b=32.
    let bx_off = BatchedXbar::program_with(
        &wq,
        cfg,
        &XbarOptions {
            abft: false,
            ..XbarOptions::default()
        },
    );
    let b = 32;
    let xs: Vec<i32> = (0..b * bx.k)
        .map(|_| rng.below(1 << cfg.x_bits) as i32)
        .collect();
    let mut out = vec![0i64; b * bx.n];
    let mut s_on = XbarScratch::with_threads(1);
    let mut s_off = XbarScratch::with_threads(1);
    let on_s = time_per_call(budget, || {
        bx.mvm_batch(&xs, b, &mut out, &mut s_on);
        std::hint::black_box(&out);
    });
    let off_s = time_per_call(budget, || {
        bx_off.mvm_batch(&xs, b, &mut out, &mut s_off);
        std::hint::black_box(&out);
    });
    let abft_overhead = on_s / off_s.max(1e-12) - 1.0;
    println!(
        "  abft b=32: verify-on {:.0} MVM/s | verify-off {:.0} MVM/s | \
         overhead {:.1}% (target <= 10%)",
        b as f64 / on_s,
        b as f64 / off_s,
        abft_overhead * 100.0
    );

    if let Some(path) = json_path {
        let report = Json::from_pairs(vec![
            ("bench", Json::Str("xbar".into())),
            ("quick", Json::Bool(quick)),
            ("k", Json::Num(k as f64)),
            ("n", Json::Num(n as f64)),
            ("threads", Json::Num(threads as f64)),
            ("host_threads", Json::Num(host_threads() as f64)),
            ("pack_speedup_b32", Json::Num(pack_speedup_b32)),
            ("thread_speedup_b32", Json::Num(thread_speedup_b32)),
            ("rows128_speedup_b32", Json::Num(wide_speedup)),
            ("abft_overhead", Json::Num(abft_overhead)),
            ("cases", Json::Arr(cases)),
        ]);
        report.write_file(std::path::Path::new(&path))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `fault-bench`: the measured fault-rate→score-corruption curve for
/// the noise-model cross-validation (EXPERIMENTS §SJ). Per rate, a
/// faulted twin of the clean `PimNet` is built with ABFT and spares
/// disabled — raw silent corruption, exactly the regime the analytic
/// `NoiseModel` penalty models — and both nets score identical seeded
/// batches. The measured penalty is the mean KL(clean ‖ faulty) of the
/// output Bernoullis (the expected logloss excess of the corrupted
/// scores under the clean model's distribution, label-free), reported
/// next to mean |Δp| and the analytic `logloss_penalty` line.
fn cmd_fault_bench(args: &Args) -> autorac::Result<()> {
    let dataset = args.str_or("dataset", "criteo");
    let seed = args.u64_or("seed", 7)?;
    let fault_seed = args.u64_or("fault-seed", 0xFA17)?;
    let batches = args.usize_or("batches", 16)?;
    let b = args.usize_or("batch", 32)?;
    let d_emb = args.usize_or("d-emb", 16)?;
    let json_path = args.get("json").map(str::to_string);
    args.finish()?;
    let prof = profile(&dataset)?;
    let g = autorac_best(&dataset);
    let (nd, ns) = (prof.n_dense, prof.n_sparse());
    let mut clean = build_pim_net(&g, nd, ns, d_emb, seed)?;
    let cfg = clean.head.xbar.cfg;
    let noise = NoiseModel::default();
    let analytic = noise.logloss_penalty(&cfg);
    println!(
        "fault-bench {dataset}: genome {}, {} batches × b={b}, analytic \
         noise penalty {analytic:.5} (σ_col {:.5}, sensitivity {})",
        g.name,
        batches,
        noise.column_rel_sigma(&cfg),
        noise.sensitivity
    );
    let mut rows: Vec<Json> = Vec::new();
    for rate in [1e-5f64, 1e-4, 1e-3] {
        let opts = XbarOptions {
            abft: false,
            spare_tiles: 0,
            fault: Some(FaultSpec::cells(
                rate,
                seed_from_name(fault_seed, "fault-bench"),
            )),
            ..XbarOptions::default()
        };
        let mut faulty = build_pim_net_with(&g, nd, ns, d_emb, seed, &opts)?;
        let corrupt_tiles = faulty.corrupt_tiles();
        // identical inputs per rate: the stream restarts from the same
        // seed, so every rate scores the same batches as the clean net
        let mut rng = Rng::new(seed ^ 0x00FB);
        let mut sc = NetScratch::with_threads(1);
        let mut sf = NetScratch::with_threads(1);
        let (mut kl_sum, mut dp_sum, mut count) = (0.0f64, 0.0f64, 0usize);
        for _ in 0..batches {
            let dense: Vec<f32> =
                (0..b * nd).map(|_| rng.normal() as f32).collect();
            let sparse: Vec<f32> = (0..b * ns * d_emb)
                .map(|_| (rng.normal() * 0.05) as f32)
                .collect();
            let pc = clean.forward_batch(&dense, &sparse, b, &mut sc);
            let pf = faulty.forward_batch(&dense, &sparse, b, &mut sf);
            for (&p, &q) in pc.iter().zip(&pf) {
                // clamp both ends: a saturated sigmoid (p → 0 or 1)
                // would otherwise turn the KL terms into 0·ln 0 = NaN
                let p = f64::from(p).clamp(1e-7, 1.0 - 1e-7);
                let q = f64::from(q).clamp(1e-7, 1.0 - 1e-7);
                kl_sum += p * (p / q).ln()
                    + (1.0 - p) * ((1.0 - p) / (1.0 - q)).ln();
                dp_sum += (p - q).abs();
                count += 1;
            }
        }
        let kl = kl_sum / count.max(1) as f64;
        let dp = dp_sum / count.max(1) as f64;
        println!(
            "  rate {rate:.0e}: corrupt tiles {corrupt_tiles} | measured \
             logloss penalty {kl:.6} | mean |Δp| {dp:.6} | analytic/measured \
             {:.2}",
            analytic / kl.max(1e-12)
        );
        rows.push(Json::from_pairs(vec![
            ("rate", Json::Num(rate)),
            ("corrupt_tiles", Json::Num(corrupt_tiles as f64)),
            ("measured_penalty", Json::Num(kl)),
            ("mean_abs_dp", Json::Num(dp)),
        ]));
    }
    if let Some(path) = json_path {
        let report = Json::from_pairs(vec![
            ("bench", Json::Str("fault".into())),
            ("dataset", Json::Str(dataset)),
            ("batches", Json::Num(batches as f64)),
            ("batch", Json::Num(b as f64)),
            ("analytic_penalty", Json::Num(analytic)),
            ("rates", Json::Arr(rows)),
        ]);
        report.write_file(std::path::Path::new(&path))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> autorac::Result<()> {
    let dataset = args.str_or("dataset", "criteo");
    let dir = artifacts_dir(args);
    let n = args.usize_or("n", 4096)?;
    args.finish()?;
    autorac::ensure!(
        Runtime::pjrt_available(),
        "PJRT backend not linked in this offline build (stub runtime::xla) — \
         `eval` needs artifact execution"
    );
    let prof = profile(&dataset)?;
    let tf = TensorFile::read(&dir.join(format!("embeddings_{dataset}.bin")))?;
    let store = EmbeddingStore::from_atns(&tf)?;
    let mut rt = Runtime::open(&dir)?;
    let artifact = Runtime::model_name(&dataset, 512);
    let mut gen = Generator::new(prof.clone(), DEFAULT_SEED);
    let splits = Splits::default();
    let off = splits.offset("test");
    let nd = prof.n_dense.max(1);
    let mut probs = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    let t0 = Instant::now();
    for start in (0..n).step_by(512) {
        let count = 512.min(n - start);
        let b = make_batch(&mut gen, off + start, count);
        let mut dense = b.dense.clone();
        dense.resize(512 * nd, 0.0);
        let mut sparse = Vec::new();
        store.gather(&b.ids, count, &mut sparse);
        sparse.resize(512 * prof.n_sparse() * store.d_emb, 0.0);
        let p = rt.infer(
            &artifact,
            &dense,
            [512, nd],
            &sparse,
            [512, prof.n_sparse(), store.d_emb],
        )?;
        probs.extend_from_slice(&p[..count]);
        labels.extend_from_slice(&b.labels);
    }
    let ll = autorac::metrics::logloss(&probs, &labels);
    let auc = autorac::metrics::auc(&probs, &labels);
    println!(
        "eval {dataset} (PIM artifact, {n} test records, {:.1}s): LogLoss {ll:.4}  AUC {auc:.4}",
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_datagen(args: &Args) -> autorac::Result<()> {
    let dataset = args.str_or("dataset", "criteo");
    let n = args.usize_or("n", 5)?;
    args.finish()?;
    let prof = profile(&dataset)?;
    println!(
        "{dataset}: {} dense + {} sparse fields, cards {:?}…, zipf α {}",
        prof.n_dense,
        prof.n_sparse(),
        &prof.cards[..4.min(prof.cards.len())],
        prof.zipf_alpha
    );
    let mut gen = Generator::new(prof, DEFAULT_SEED);
    let mut clicks = 0usize;
    for rec in gen.block(0, n.max(1000)) {
        clicks += rec.label as usize;
    }
    println!("empirical CTR over {} records: {:.3}", n.max(1000), clicks as f64 / n.max(1000) as f64);
    for rec in gen.block(0, n) {
        println!(
            "  #{}: y={} ids[..6]={:?} dense[..4]={:?}",
            rec.index,
            rec.label as u8,
            &rec.ids[..6.min(rec.ids.len())],
            &rec.dense[..4.min(rec.dense.len())]
        );
    }
    Ok(())
}
