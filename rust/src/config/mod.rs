//! Experiment configuration system: JSON config files for the search,
//! simulation, and serving flows, with CLI overrides layered on top.
//!
//! A config file holds exactly the knobs the CLI exposes, so a run is
//! fully described by `autorac <cmd> --config runs/foo.json` and
//! reproducible from the file (the effective config is echoed into the
//! output). Unknown keys are rejected — config typos fail loudly.

use crate::coordinator::BatcherConfig;
use crate::nas::SearchConfig;
use crate::util::cli::Args;
use crate::util::json::Json;
use std::path::Path;
use std::time::Duration;

/// Top-level experiment config (all sections optional).
#[derive(Clone, Debug, Default)]
pub struct Config {
    pub search: Option<SearchConfig>,
    pub serve: Option<ServeConfig>,
    pub workload: Option<WorkloadConfig>,
}

#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub dataset: String,
    pub workers: usize,
    pub batch: usize,
    pub max_wait_us: u64,
    pub requests: usize,
    pub rps: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            dataset: "criteo".into(),
            workers: 1,
            batch: 32,
            max_wait_us: 200,
            requests: 2000,
            rps: f64::INFINITY,
        }
    }
}

impl ServeConfig {
    pub fn batcher(&self) -> BatcherConfig {
        BatcherConfig {
            max_batch: self.batch,
            max_wait: Duration::from_micros(self.max_wait_us),
        }
    }
}

#[derive(Clone, Debug)]
pub struct WorkloadConfig {
    pub n_requests: usize,
    pub arrival_rps: f64,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            n_requests: 256,
            arrival_rps: f64::INFINITY,
            seed: 7,
        }
    }
}

const SEARCH_KEYS: [&str; 12] = [
    "dataset", "population", "generations", "children_per_gen",
    "mutations_per_child", "sample_size", "lambdas", "seed", "sim_requests",
    "workers", "pareto_capacity", "cache",
];
const SERVE_KEYS: [&str; 6] =
    ["dataset", "workers", "batch", "max_wait_us", "requests", "rps"];
const WORKLOAD_KEYS: [&str; 3] = ["n_requests", "arrival_rps", "seed"];

fn check_keys(j: &Json, allowed: &[&str], section: &str) -> crate::Result<()> {
    if let Some(pairs) = j.as_obj() {
        for (k, _) in pairs {
            crate::ensure!(
                allowed.contains(&k.as_str()),
                "unknown key `{k}` in [{section}] (allowed: {allowed:?})"
            );
        }
    }
    Ok(())
}

impl Config {
    pub fn load(path: &Path) -> crate::Result<Config> {
        let j = Json::read_file(path)?;
        check_keys(&j, &["search", "serve", "workload"], "root")?;
        let mut cfg = Config::default();
        if let Some(s) = j.get("search") {
            check_keys(s, &SEARCH_KEYS, "search")?;
            let d = SearchConfig::default();
            let lambdas = match s.get("lambdas") {
                Some(l) => {
                    let v = l
                        .as_arr()
                        .ok_or_else(|| crate::err!("lambdas must be an array"))?;
                    crate::ensure!(v.len() == 3, "lambdas needs 3 entries");
                    [
                        v[0].as_f64().unwrap_or(0.05),
                        v[1].as_f64().unwrap_or(0.05),
                        v[2].as_f64().unwrap_or(0.05),
                    ]
                }
                None => d.lambdas,
            };
            cfg.search = Some(SearchConfig {
                dataset: s
                    .get("dataset")
                    .and_then(Json::as_str)
                    .unwrap_or(&d.dataset)
                    .to_string(),
                population: s.get("population").and_then(Json::as_usize).unwrap_or(d.population),
                generations: s
                    .get("generations")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.generations),
                children_per_gen: s
                    .get("children_per_gen")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.children_per_gen),
                mutations_per_child: s
                    .get("mutations_per_child")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.mutations_per_child),
                sample_size: s
                    .get("sample_size")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.sample_size),
                lambdas,
                seed: s
                    .get("seed")
                    .and_then(Json::as_i64)
                    .map(|v| v as u64)
                    .unwrap_or(d.seed),
                sim_requests: s
                    .get("sim_requests")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.sim_requests),
                workers: s.get("workers").and_then(Json::as_usize).unwrap_or(d.workers),
                pareto_capacity: s
                    .get("pareto_capacity")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.pareto_capacity),
                cache: s.get("cache").and_then(Json::as_bool).unwrap_or(d.cache),
            });
        }
        if let Some(s) = j.get("serve") {
            check_keys(s, &SERVE_KEYS, "serve")?;
            let d = ServeConfig::default();
            cfg.serve = Some(ServeConfig {
                dataset: s
                    .get("dataset")
                    .and_then(Json::as_str)
                    .unwrap_or(&d.dataset)
                    .to_string(),
                workers: s.get("workers").and_then(Json::as_usize).unwrap_or(d.workers),
                batch: s.get("batch").and_then(Json::as_usize).unwrap_or(d.batch),
                max_wait_us: s
                    .get("max_wait_us")
                    .and_then(Json::as_i64)
                    .map(|v| v as u64)
                    .unwrap_or(d.max_wait_us),
                requests: s.get("requests").and_then(Json::as_usize).unwrap_or(d.requests),
                rps: s.get("rps").and_then(Json::as_f64).unwrap_or(d.rps),
            });
        }
        if let Some(w) = j.get("workload") {
            check_keys(w, &WORKLOAD_KEYS, "workload")?;
            let d = WorkloadConfig::default();
            cfg.workload = Some(WorkloadConfig {
                n_requests: w
                    .get("n_requests")
                    .and_then(Json::as_usize)
                    .unwrap_or(d.n_requests),
                arrival_rps: w
                    .get("arrival_rps")
                    .and_then(Json::as_f64)
                    .unwrap_or(d.arrival_rps),
                seed: w
                    .get("seed")
                    .and_then(Json::as_i64)
                    .map(|v| v as u64)
                    .unwrap_or(d.seed),
            });
        }
        Ok(cfg)
    }

    /// Optional `--config <path>` from the CLI; empty config otherwise.
    pub fn from_args(args: &Args) -> crate::Result<Config> {
        match args.get("config") {
            Some(p) => Config::load(Path::new(&p.to_string())),
            None => Ok(Config::default()),
        }
    }

    /// Echo the effective config (reproducibility record).
    pub fn to_json(&self) -> Json {
        let mut root = Json::obj();
        if let Some(s) = &self.search {
            root.set(
                "search",
                Json::from_pairs(vec![
                    ("dataset", Json::Str(s.dataset.clone())),
                    ("population", Json::Num(s.population as f64)),
                    ("generations", Json::Num(s.generations as f64)),
                    ("children_per_gen", Json::Num(s.children_per_gen as f64)),
                    ("mutations_per_child", Json::Num(s.mutations_per_child as f64)),
                    ("sample_size", Json::Num(s.sample_size as f64)),
                    ("lambdas", Json::arr_f64(&s.lambdas)),
                    ("seed", Json::Num(s.seed as f64)),
                    ("sim_requests", Json::Num(s.sim_requests as f64)),
                    ("workers", Json::Num(s.workers as f64)),
                    ("pareto_capacity", Json::Num(s.pareto_capacity as f64)),
                    ("cache", Json::Bool(s.cache)),
                ]),
            );
        }
        if let Some(s) = &self.serve {
            root.set(
                "serve",
                Json::from_pairs(vec![
                    ("dataset", Json::Str(s.dataset.clone())),
                    ("workers", Json::Num(s.workers as f64)),
                    ("batch", Json::Num(s.batch as f64)),
                    ("max_wait_us", Json::Num(s.max_wait_us as f64)),
                    ("requests", Json::Num(s.requests as f64)),
                    ("rps", Json::Num(s.rps)),
                ]),
            );
        }
        root
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_tmp(text: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "autorac_cfg_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join(format!("{}.json", text.len()));
        std::fs::write(&p, text).unwrap();
        p
    }

    #[test]
    fn loads_full_config() {
        let p = write_tmp(
            r#"{"search": {"dataset": "avazu", "generations": 10,
                 "lambdas": [0.1, 0.2, 0.3], "workers": 6,
                 "pareto_capacity": 16, "cache": false},
                "serve": {"workers": 4, "batch": 16},
                "workload": {"n_requests": 99}}"#,
        );
        let c = Config::load(&p).unwrap();
        let s = c.search.unwrap();
        assert_eq!(s.dataset, "avazu");
        assert_eq!(s.generations, 10);
        assert_eq!(s.lambdas, [0.1, 0.2, 0.3]);
        assert_eq!(s.workers, 6);
        assert_eq!(s.pareto_capacity, 16);
        assert!(!s.cache);
        assert_eq!(s.population, SearchConfig::default().population);
        let sv = c.serve.unwrap();
        assert_eq!(sv.workers, 4);
        assert_eq!(sv.batch, 16);
        assert_eq!(c.workload.unwrap().n_requests, 99);
    }

    #[test]
    fn rejects_unknown_keys() {
        let p = write_tmp(r#"{"search": {"generaitons": 10}}"#);
        let err = Config::load(&p).unwrap_err().to_string();
        assert!(err.contains("generaitons"), "{err}");
        let p2 = write_tmp(r#"{"srch": {}}"#);
        assert!(Config::load(&p2).is_err());
    }

    #[test]
    fn empty_config_is_all_none() {
        let p = write_tmp("{}");
        let c = Config::load(&p).unwrap();
        assert!(c.search.is_none() && c.serve.is_none());
    }

    #[test]
    fn roundtrips_through_echo() {
        let p = write_tmp(r#"{"search": {"generations": 7}, "serve": {}}"#);
        let c = Config::load(&p).unwrap();
        let echoed = c.to_json().to_string_pretty();
        let p2 = write_tmp(&echoed);
        let c2 = Config::load(&p2).unwrap();
        assert_eq!(c2.search.unwrap().generations, 7);
    }

    #[test]
    fn batcher_conversion() {
        let s = ServeConfig {
            batch: 8,
            max_wait_us: 50,
            ..Default::default()
        };
        let b = s.batcher();
        assert_eq!(b.max_batch, 8);
        assert_eq!(b.max_wait, Duration::from_micros(50));
    }
}
