//! Calibrated accuracy surrogate (S12).
//!
//! The paper's search fine-tunes each child on GPU and reads off its
//! test loss (Algorithm 1, line 9). Offline we substitute the ridge
//! model fitted by the build-time calibration pass
//! (`python/compile/train.py::fit_surrogate` → `surrogate.json`): the
//! same genome featurization (MUST mirror `genome_features`) with
//! per-dataset intercepts, plus the ReRAM non-ideality penalty from
//! `pim::noise` for the chosen hardware genome. DESIGN.md §1 documents
//! the substitution.

use super::genome::{DenseOp, Genome, Interaction, SparseOp};
use crate::pim::NoiseModel;
use crate::util::json::Json;

pub struct Surrogate {
    /// slope weights in FEATURE_NAMES order, then per-dataset intercepts
    weights: Vec<f64>,
    datasets: Vec<String>,
    noise: NoiseModel,
    pub rmse: f64,
    /// trust region: feature box + per-dataset prediction bounds from
    /// the calibration runs (linear fits must not extrapolate — without
    /// this the search exploits the surrogate's unbounded slopes)
    feature_min: Vec<f64>,
    feature_max: Vec<f64>,
    logloss_bounds: Vec<(f64, f64)>,
}

pub const FEATURE_NAMES: [&str; 11] = [
    "bias",
    "log10_params",
    "frac_dp",
    "frac_fm",
    "frac_dsi",
    "frac_efc",
    "frac_fc_4bit",
    "frac_efc_4bit",
    "frac_inter_4bit",
    "d_emb_64",
    "mean_dense_dim_512",
];

/// Genome featurization — mirror of train.py::genome_features.
pub fn genome_features(g: &Genome) -> Vec<f64> {
    let n = g.blocks.len() as f64;
    let count = |f: &dyn Fn(&super::genome::Block) -> bool| {
        g.blocks.iter().filter(|b| f(b)).count() as f64
    };
    let n_dp = count(&|b| b.dense_op == DenseOp::Dp);
    let n_fm = count(&|b| b.interaction == Interaction::Fm);
    let n_dsi = count(&|b| b.interaction == Interaction::Dsi);
    let n_efc = count(&|b| b.sparse_op == SparseOp::Efc);
    let fc4 = count(&|b| b.dense_wbits == 4) / n;
    let efc4 = count(&|b| b.sparse_wbits == 4) / n;
    let int4 = count(&|b| b.inter_wbits == 4) / n;
    let mean_dim =
        g.blocks.iter().map(|b| b.dense_dim).sum::<usize>() as f64 / n;
    let shapes = g.shapes().expect("valid genome");
    let params: usize = shapes.iter().map(|s| s.din * s.dout).sum();
    vec![
        1.0,
        (1.0 + params as f64).log10(),
        n_dp / n,
        n_fm / n,
        n_dsi / n,
        n_efc / n,
        fc4,
        efc4,
        int4,
        g.d_emb as f64 / 64.0,
        mean_dim / 512.0,
    ]
}

impl Surrogate {
    /// Load from `artifacts/calibration/surrogate.json`.
    pub fn load(path: &std::path::Path) -> crate::Result<Surrogate> {
        let j = Json::read_file(path)?;
        let weights = j.req_f64s("weights")?;
        let datasets = j
            .req_arr("datasets")?
            .iter()
            .map(|d| d.as_str().unwrap_or_default().to_string())
            .collect::<Vec<_>>();
        crate::ensure!(
            weights.len() == FEATURE_NAMES.len() + datasets.len(),
            "weight vector length {} != {} features + {} datasets",
            weights.len(),
            FEATURE_NAMES.len(),
            datasets.len()
        );
        let n_feat = FEATURE_NAMES.len();
        let feature_min = j
            .req_f64s("feature_min")
            .unwrap_or_else(|_| vec![f64::NEG_INFINITY; n_feat]);
        let feature_max = j
            .req_f64s("feature_max")
            .unwrap_or_else(|_| vec![f64::INFINITY; n_feat]);
        let logloss_bounds = datasets
            .iter()
            .map(|d| {
                let lo = j
                    .at(&["logloss_min", d])
                    .and_then(Json::as_f64)
                    .unwrap_or(0.02);
                let hi = j
                    .at(&["logloss_max", d])
                    .and_then(Json::as_f64)
                    .unwrap_or(1.0);
                // allow modest improvement past the best observed run —
                // the search is supposed to find better models, just not
                // impossibly better ones
                (lo * 0.95, hi * 1.05)
            })
            .collect();
        Ok(Surrogate {
            weights,
            datasets,
            noise: NoiseModel::default(),
            rmse: j.req_f64("rmse").unwrap_or(0.0),
            feature_min,
            feature_max,
            logloss_bounds,
        })
    }

    /// Load the default artifact location, falling back to the built-in
    /// prior when artifacts have not been built (tests / cold checkouts).
    pub fn load_default() -> Surrogate {
        let path = std::path::Path::new("artifacts/calibration/surrogate.json");
        Surrogate::load(path).unwrap_or_else(|_| Surrogate::prior())
    }

    /// A physically-sensible prior (used when no calibration exists):
    /// more capacity and interactions help slightly; 4-bit weights hurt
    /// (Figure 2's knee); values are in the range the calibration fits.
    pub fn prior() -> Surrogate {
        let mut weights = vec![
            0.0,    // bias (folded into dataset intercepts)
            -0.004, // log10_params
            -0.006, // frac_dp
            -0.010, // frac_fm
            -0.003, // frac_dsi
            -0.006, // frac_efc
            0.012,  // frac_fc_4bit
            0.008,  // frac_efc_4bit
            0.005,  // frac_inter_4bit
            -0.004, // d_emb_64
            -0.003, // mean_dense_dim_512
        ];
        weights.extend([0.60, 0.42, 0.20]); // avazu, criteo, kdd intercepts
        Surrogate {
            weights,
            datasets: vec![
                "avazu".to_string(),
                "criteo".to_string(),
                "kdd".to_string(),
            ],
            noise: NoiseModel::default(),
            rmse: f64::NAN,
            feature_min: vec![f64::NEG_INFINITY; FEATURE_NAMES.len()],
            feature_max: vec![f64::INFINITY; FEATURE_NAMES.len()],
            logloss_bounds: vec![(0.30, 0.75), (0.30, 0.75), (0.08, 0.40)],
        }
    }

    /// Predicted test LogLoss for a genome (model surrogate + ReRAM
    /// non-ideality penalty for the hardware genome), trust-region
    /// clipped to the calibration cloud.
    pub fn logloss(&self, g: &Genome) -> f64 {
        let mut x = genome_features(g);
        for (i, v) in x.iter_mut().enumerate() {
            *v = v.clamp(self.feature_min[i], self.feature_max[i]);
        }
        let mut bounds = (0.02, 1.5);
        for (ds, b) in self.datasets.iter().zip(&self.logloss_bounds) {
            let hot = *ds == g.dataset;
            x.push(if hot { 1.0 } else { 0.0 });
            if hot {
                bounds = *b;
            }
        }
        let model: f64 = x.iter().zip(&self.weights).map(|(a, b)| a * b).sum();
        model.clamp(bounds.0, bounds.1) + self.noise.logloss_penalty(&g.pim)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::genome::autorac_best;
    use crate::util::rng::Rng;

    #[test]
    fn prior_predicts_plausible_loglosses() {
        let s = Surrogate::prior();
        for ds in ["criteo", "avazu", "kdd"] {
            let ll = s.logloss(&autorac_best(ds));
            assert!(ll > 0.05 && ll < 1.0, "{ds}: {ll}");
        }
    }

    #[test]
    fn four_bit_everywhere_predicts_worse_loss() {
        let s = Surrogate::prior();
        let g8 = autorac_best("criteo");
        let mut g4 = g8.clone();
        for b in &mut g4.blocks {
            b.dense_wbits = 4;
            b.sparse_wbits = 4;
            b.inter_wbits = 4;
        }
        assert!(s.logloss(&g4) > s.logloss(&g8));
    }

    #[test]
    fn features_have_fixed_length_and_range() {
        let mut rng = Rng::new(1);
        for i in 0..20 {
            let g = crate::nas::space::random_genome(&mut rng, "kdd", &format!("r{i}"));
            let f = genome_features(&g);
            assert_eq!(f.len(), FEATURE_NAMES.len());
            assert!(f.iter().all(|v| v.is_finite()));
            assert_eq!(f[0], 1.0);
        }
    }

    #[test]
    fn loads_calibration_artifact_when_present() {
        let path = std::path::Path::new("artifacts/calibration/surrogate.json");
        if path.exists() {
            let s = Surrogate::load(path).unwrap();
            let ll = s.logloss(&autorac_best("criteo"));
            assert!(ll > 0.1 && ll < 1.5, "{ll}");
        }
    }
}
