//! Parallel, memoized, Pareto-aware co-search engine (S20).
//!
//! Re-architecture of the serial Algorithm-1 loop in [`super::evolution`]
//! for the ROADMAP's "as fast as the hardware allows" bar, under one hard
//! rule: **worker count must not change a single bit of the result**
//! (pinned by `tests/search_determinism.rs`). Three pieces make that hold:
//!
//! * **per-candidate RNG streams** — every random decision is drawn from
//!   an `Rng` seeded by a stable name over `(search_seed, generation,
//!   child_index)`, never from a shared stream, so the mutation sequence
//!   of child *c* is independent of how many threads evaluate it;
//! * **a std::thread worker pool** (zero new deps, the coordinator's
//!   channel idiom: `Arc<Mutex<Receiver>>` job queue + result channel)
//!   that evaluates one generation's children concurrently; results are
//!   re-ordered by child index on the main thread before any state —
//!   population, cache, archive — is touched;
//! * **a genome-keyed evaluation cache** ([`super::cache::EvalCache`])
//!   over [`crate::mapping::genome_eval_key`], exploiting that both the
//!   surrogate and the fixed-seed simulator are pure functions of the
//!   genome structure.
//!
//! Alongside the scalar criterion, every evaluation is offered to a
//! bounded [`ParetoArchive`] over `[test_loss, 1/throughput, area,
//! power]` — the front and its knee point come for free with the run.

use super::accuracy::Surrogate;
use super::cache::{CacheStats, EvalCache, EvalOutcome};
use super::evolution::{Individual, SearchConfig, SearchTrace};
use super::genome::Genome;
use super::pareto::{ParetoArchive, ParetoPoint};
use super::space::{mutate, random_genome};
use crate::mapping::{genome_eval_key, map_genome, MapStyle};
use crate::pim::TechParams;
use crate::sim::{simulate, Workload};
use crate::util::rng::{seed_from_name, Rng};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Everything one candidate evaluation needs; shared read-only across
/// the worker threads via `Arc`.
struct EvalCtx {
    tech: TechParams,
    surrogate: Surrogate,
    sim_requests: usize,
}

impl EvalCtx {
    /// Algorithm 1 lines 9–10: surrogate test loss + behavioral-sim
    /// metrics `[1/throughput, area, power]`. Pure in the genome.
    fn eval(&self, g: &Genome) -> crate::Result<EvalOutcome> {
        let test_loss = self.surrogate.logloss(g);
        let mapped = map_genome(g, &self.tech, MapStyle::Smart)?;
        let r = simulate(
            &mapped,
            None,
            &Workload {
                n_requests: self.sim_requests,
                ..Workload::default()
            },
        );
        Ok((test_loss, [1.0 / r.throughput_rps, r.area_mm2, r.power_mw]))
    }

    /// [`EvalCtx::eval`] with panics converted to errors. Both the
    /// pooled and the inline path go through this, so a panicking
    /// evaluation produces the same `Err` for any worker count —
    /// and a pool worker always sends a result, which is what keeps
    /// the batch from deadlocking on a lost job.
    fn eval_caught(&self, g: &Genome) -> crate::Result<EvalOutcome> {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| self.eval(g)))
            .unwrap_or_else(|_| {
                Err(crate::err!("search evaluation panicked on `{}`", g.name))
            })
    }
}

/// Job/result payloads carry a run-unique serial so a batch can never
/// mis-associate a stale result from an aborted predecessor.
type Job = (u64, Genome);
type JobOut = (u64, crate::Result<EvalOutcome>);

struct Pool {
    /// `Option` so `Drop` can hang up the queue before joining.
    job_tx: Option<mpsc::Sender<Job>>,
    out_rx: mpsc::Receiver<JobOut>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Pool {
    /// Spawn failures propagate as errors; an early `?` drops the
    /// partially-built pool, whose `Drop` hangs up the queue and joins
    /// the workers that did start.
    fn spawn(workers: usize, ctx: Arc<EvalCtx>) -> crate::Result<Pool> {
        let (job_tx, job_rx) = mpsc::channel::<Job>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (out_tx, out_rx) = mpsc::channel::<JobOut>();
        let mut pool = Pool {
            job_tx: Some(job_tx),
            out_rx,
            handles: Vec::with_capacity(workers),
        };
        for w in 0..workers {
            let rx = Arc::clone(&job_rx);
            let tx = out_tx.clone();
            let ctx = Arc::clone(&ctx);
            let handle = std::thread::Builder::new()
                .name(format!("nas-eval-{w}"))
                .spawn(move || loop {
                    // take ONE job under the lock, evaluate outside it
                    let job = match rx.lock() {
                        Ok(guard) => guard.recv(),
                        Err(_) => break,
                    };
                    match job {
                        Ok((serial, genome)) => {
                            if tx.send((serial, ctx.eval_caught(&genome))).is_err() {
                                break; // engine dropped mid-batch
                            }
                        }
                        Err(_) => break, // queue hung up: shutdown
                    }
                })
                .map_err(|e| {
                    crate::err!("failed to spawn search worker {w}: {e}")
                })?;
            pool.handles.push(handle);
        }
        Ok(pool)
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.job_tx.take(); // hang up → workers drain and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The parallel engine. Drop-in for [`super::evolution::Search`] (same
/// `SearchConfig` / `Individual` / `SearchTrace` surface), plus the
/// archive and cache statistics.
pub struct ParallelSearch {
    pub cfg: SearchConfig,
    ctx: Arc<EvalCtx>,
    pool: Option<Pool>,
    cache: EvalCache,
    /// bounded Pareto front over (test_loss, 1/throughput, area, power)
    pub archive: ParetoArchive,
    /// design targets [1/throughput, area, power] (Algorithm 1 inputs)
    pub targets: [f64; 3],
    pub population: Vec<Individual>,
    pub trace: SearchTrace,
    generation: usize,
    /// monotone job id (stale-result guard across batches)
    job_serial: u64,
    /// map+simulate runs actually executed (≤ misses once in-batch
    /// sibling dedup kicks in; excludes the target-setting reference)
    sims_run: usize,
}

impl ParallelSearch {
    /// Targets default to the metrics of the hand-crafted NASRec design,
    /// exactly like the serial reference. Degenerate configs are
    /// rejected here so every CLI entry point errors instead of
    /// panicking deep inside the tournament/simulator.
    pub fn new(cfg: SearchConfig, surrogate: Surrogate) -> crate::Result<ParallelSearch> {
        crate::ensure!(cfg.population > 0, "search population must be ≥ 1");
        crate::ensure!(cfg.sample_size > 0, "tournament sample_size must be ≥ 1");
        crate::ensure!(cfg.children_per_gen > 0, "children_per_gen must be ≥ 1");
        crate::ensure!(cfg.sim_requests > 0, "sim_requests must be ≥ 1");
        let ctx = EvalCtx {
            tech: TechParams::default(),
            surrogate,
            sim_requests: cfg.sim_requests,
        };
        let reference = super::genome::nasrec_like(&cfg.dataset);
        let (_, targets) = ctx.eval(&reference)?;
        let ctx = Arc::new(ctx);
        let pool = if cfg.workers > 1 {
            Some(Pool::spawn(cfg.workers, Arc::clone(&ctx))?)
        } else {
            None
        };
        Ok(ParallelSearch {
            cache: EvalCache::new(cfg.cache),
            archive: ParetoArchive::new(cfg.pareto_capacity),
            pool,
            ctx,
            targets,
            population: Vec::new(),
            trace: SearchTrace::default(),
            generation: 0,
            job_serial: 0,
            sims_run: 0,
            cfg,
        })
    }

    fn criterion(&self, test_loss: f64, metrics: &[f64; 3]) -> f64 {
        super::evolution::criterion(&self.cfg.lambdas, &self.targets, test_loss, metrics)
    }

    /// Evaluate a batch of candidates: cache pass first, then one job
    /// per *unique* structural key (identical siblings share a single
    /// simulation — evaluation is pure, so fanning the outcome out is
    /// bit-identical to evaluating twice), fanned to the pool or run
    /// inline with ≤ 1 worker. All engine state is updated in slot
    /// order afterwards, so the outcome is independent of worker
    /// scheduling.
    fn eval_batch(&mut self, genomes: &[Genome]) -> crate::Result<Vec<EvalOutcome>> {
        let n = genomes.len();
        // where slot i's outcome comes from
        enum Source {
            Done(EvalOutcome),
            Job(usize),
        }
        let mut sources: Vec<Source> = Vec::with_capacity(n);
        // unique keys to evaluate, with a representative slot, in
        // first-miss slot order
        let mut jobs: Vec<(u64, usize)> = Vec::new();
        let mut key_pos: std::collections::HashMap<u64, usize> =
            std::collections::HashMap::new();
        for (i, g) in genomes.iter().enumerate() {
            let key = genome_eval_key(g);
            if let Some(v) = self.cache.get(key) {
                sources.push(Source::Done(v));
                continue;
            }
            // in-batch dedup only while memoization is on: cache:false
            // is the honest run-every-simulation baseline
            if self.cache.enabled() {
                if let Some(&j) = key_pos.get(&key) {
                    sources.push(Source::Job(j));
                    continue;
                }
                key_pos.insert(key, jobs.len());
            }
            sources.push(Source::Job(jobs.len()));
            jobs.push((key, i));
        }
        let mut results: Vec<Option<crate::Result<EvalOutcome>>> =
            Vec::with_capacity(jobs.len());
        results.resize_with(jobs.len(), || None);
        match &self.pool {
            Some(pool) => {
                let tx = pool
                    .job_tx
                    .as_ref()
                    .expect("pool queue alive until Drop");
                // serial → job index for THIS batch only
                let mut want =
                    std::collections::HashMap::with_capacity(jobs.len());
                for (j, &(_, slot)) in jobs.iter().enumerate() {
                    self.job_serial += 1;
                    want.insert(self.job_serial, j);
                    tx.send((self.job_serial, genomes[slot].clone()))
                        .map_err(|_| crate::err!("search worker pool shut down"))?;
                }
                while !want.is_empty() {
                    let (serial, result) = pool
                        .out_rx
                        .recv()
                        .map_err(|_| crate::err!("search worker thread died"))?;
                    if let Some(j) = want.remove(&serial) {
                        results[j] = Some(result);
                    }
                    // else: stale result from an aborted batch — ignore
                }
            }
            None => {
                for (j, &(_, slot)) in jobs.iter().enumerate() {
                    results[j] = Some(self.ctx.eval_caught(&genomes[slot]));
                }
            }
        }
        // surface errors deterministically (lowest job first), memoize,
        // then fan the outcomes back out to their slots
        let mut outcomes: Vec<EvalOutcome> = Vec::with_capacity(jobs.len());
        for (&(key, _), r) in jobs.iter().zip(results) {
            let v = r.expect("every job completed")?;
            self.cache.insert(key, v);
            outcomes.push(v);
        }
        self.trace.evaluations += n;
        self.sims_run += outcomes.len();
        Ok(sources
            .into_iter()
            .map(|s| match s {
                Source::Done(v) => v,
                Source::Job(j) => outcomes[j],
            })
            .collect())
    }

    /// Fold one evaluated candidate into population + Pareto archive.
    fn admit(&mut self, genome: Genome, outcome: EvalOutcome, generation: usize) {
        let (test_loss, metrics) = outcome;
        let criterion = self.criterion(test_loss, &metrics);
        self.archive.offer(ParetoPoint {
            objectives: [test_loss, metrics[0], metrics[1], metrics[2]],
            criterion,
            generation,
            genome: genome.clone(),
        });
        self.population.push(Individual {
            genome,
            test_loss,
            metrics,
            criterion,
            generation,
        });
    }

    /// Line 1: random initial population, one RNG stream per individual.
    pub fn init_population(&mut self) -> crate::Result<()> {
        let mut genomes = Vec::with_capacity(self.cfg.population);
        for i in 0..self.cfg.population {
            let mut rng =
                Rng::new(seed_from_name(self.cfg.seed, &format!("par/init/{i}")));
            genomes.push(random_genome(&mut rng, &self.cfg.dataset, &format!("init{i}")));
        }
        let outcomes = self.eval_batch(&genomes)?;
        for (genome, outcome) in genomes.into_iter().zip(outcomes) {
            self.admit(genome, outcome, 0);
        }
        self.record_generation();
        Ok(())
    }

    fn record_generation(&mut self) {
        self.trace.record(&self.population);
    }

    /// Lines 3–15: one generation. Selection draws from a generation-
    /// named stream; each child mutates under its own `(seed, gen, c)`
    /// stream, so the children are identical for any worker count.
    pub fn step(&mut self) -> crate::Result<()> {
        self.generation += 1;
        let gen = self.generation;
        let mut sel =
            Rng::new(seed_from_name(self.cfg.seed, &format!("par/sel/{gen}")));
        let parent_idx = (0..self.cfg.sample_size)
            .map(|_| sel.below(self.population.len() as u64) as usize)
            .min_by(|&a, &b| {
                self.population[a]
                    .criterion
                    .partial_cmp(&self.population[b].criterion)
                    .unwrap()
            })
            .expect("sample_size > 0");
        let parent = self.population[parent_idx].genome.clone();
        let mut children = Vec::with_capacity(self.cfg.children_per_gen);
        for c in 0..self.cfg.children_per_gen {
            let mut rng = Rng::new(seed_from_name(
                self.cfg.seed,
                &format!("par/gen/{gen}/child/{c}"),
            ));
            let mut g = parent.clone();
            for _ in 0..self.cfg.mutations_per_child {
                g = mutate(&g, &mut rng);
            }
            g.name = format!("g{gen}c{c}");
            children.push(g);
        }
        let outcomes = self.eval_batch(&children)?;
        for (genome, outcome) in children.into_iter().zip(outcomes) {
            self.admit(genome, outcome, gen);
        }
        // stable sort: equal criteria keep insertion order → deterministic
        self.population
            .sort_by(|a, b| a.criterion.partial_cmp(&b.criterion).unwrap());
        self.population.truncate(self.cfg.population);
        self.record_generation();
        Ok(())
    }

    /// Run the full search; returns the best individual.
    pub fn run(&mut self) -> crate::Result<Individual> {
        if self.population.is_empty() {
            self.init_population()?;
        }
        for _ in 0..self.cfg.generations {
            self.step()?;
        }
        Ok(self.best().clone())
    }

    pub fn best(&self) -> &Individual {
        self.population
            .iter()
            .min_by(|a, b| a.criterion.partial_cmp(&b.criterion).unwrap())
            .expect("non-empty population")
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Distinct genomes memoized so far.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// `map_genome` + `simulate` runs actually executed (logical
    /// evaluations minus cache hits minus in-batch sibling shares).
    pub fn sims_run(&self) -> usize {
        self.sims_run
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::pareto::dominates;

    fn quick_cfg(workers: usize) -> SearchConfig {
        SearchConfig {
            generations: 10,
            population: 12,
            children_per_gen: 4,
            sample_size: 4,
            sim_requests: 16,
            workers,
            ..SearchConfig::default()
        }
    }

    #[test]
    fn degenerate_configs_error_instead_of_panicking() {
        for bad in [
            SearchConfig { population: 0, ..quick_cfg(1) },
            SearchConfig { sample_size: 0, ..quick_cfg(1) },
            SearchConfig { children_per_gen: 0, ..quick_cfg(1) },
            SearchConfig { sim_requests: 0, ..quick_cfg(1) },
        ] {
            assert!(ParallelSearch::new(bad, Surrogate::prior()).is_err());
        }
    }

    #[test]
    fn engine_types_are_send_and_sync() {
        fn check<T: Send + Sync>() {}
        check::<EvalCtx>();
        check::<Genome>();
        check::<crate::util::error::Error>();
        check::<Surrogate>();
    }

    #[test]
    fn parallel_search_improves_criterion() {
        let mut s = ParallelSearch::new(quick_cfg(2), Surrogate::prior()).unwrap();
        let best = s.run().unwrap();
        assert!(
            best.criterion < s.trace.best_criterion[0],
            "no improvement: {} -> {}",
            s.trace.best_criterion[0],
            best.criterion
        );
        assert_eq!(s.population.len(), s.cfg.population);
        for w in s.trace.best_criterion.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "best went up: {w:?}");
        }
    }

    #[test]
    fn evaluated_genomes_are_feasible_and_archive_is_consistent() {
        let mut s = ParallelSearch::new(quick_cfg(3), Surrogate::prior()).unwrap();
        s.run().unwrap();
        for ind in &s.population {
            ind.genome.validate().unwrap();
        }
        assert!(!s.archive.is_empty());
        assert!(s.archive.len() <= s.archive.capacity());
        assert!(s.archive.knee().is_some());
    }

    #[test]
    fn scalar_winner_is_on_or_behind_the_front() {
        let mut s = ParallelSearch::new(quick_cfg(2), Surrogate::prior()).unwrap();
        let best = s.run().unwrap();
        let w = [
            best.test_loss,
            best.metrics[0],
            best.metrics[1],
            best.metrics[2],
        ];
        let on_front = s.archive.points().iter().any(|p| p.objectives == w);
        let behind = s
            .archive
            .points()
            .iter()
            .any(|p| dominates(&p.objectives, &w));
        assert!(on_front || behind, "winner lost from the archive");
        // with all-positive λ the winner is never dominated, so it is
        // literally the archive's best-criterion point
        let ab = s.archive.best_criterion().unwrap();
        assert_eq!(ab.criterion.to_bits(), best.criterion.to_bits());
    }

    #[test]
    fn duplicate_heavy_search_hits_the_cache() {
        // single-step mutation neighbourhoods overlap heavily — with one
        // mutation per child the search must revisit genomes
        let cfg = SearchConfig {
            mutations_per_child: 1,
            ..quick_cfg(1)
        };
        let mut s = ParallelSearch::new(cfg, Surrogate::prior()).unwrap();
        s.run().unwrap();
        let st = s.cache_stats();
        assert!(st.hits > 0, "no cache hits on a duplicate-heavy run");
        assert_eq!(st.lookups(), s.trace.evaluations);
        assert!(s.cache_len() <= s.trace.evaluations);
        // in-batch sibling dedup can only reduce work further
        assert!(s.sims_run() <= st.misses, "{} > {}", s.sims_run(), st.misses);
        assert_eq!(s.cache_len(), s.sims_run(), "one memo per simulation");
    }

    #[test]
    fn cache_off_runs_every_simulation() {
        let cfg = SearchConfig {
            cache: false,
            generations: 3,
            ..quick_cfg(1)
        };
        let mut s = ParallelSearch::new(cfg, Surrogate::prior()).unwrap();
        s.run().unwrap();
        assert_eq!(s.cache_stats(), CacheStats::default());
        assert_eq!(s.cache_len(), 0);
        // no memo and no dedup: every logical evaluation simulates
        assert_eq!(s.sims_run(), s.trace.evaluations);
    }
}
