//! Architecture genome — rust mirror of `python/compile/arch.py`.
//!
//! JSON-compatible with the python side (the build path emits
//! `artifacts/genomes/*.json`, the search emits new ones that python can
//! retrain). `rust/tests/genome_parity.rs` pins the golden files.

use crate::data::profile;
use crate::pim::PimConfig;
use crate::util::json::Json;

pub const DENSE_DIMS: [usize; 8] = [16, 32, 64, 128, 256, 512, 768, 1024];
pub const SPARSE_DIMS: [usize; 4] = [16, 32, 48, 64];
pub const WEIGHT_BITS: [usize; 2] = [4, 8];
pub const SPARSE_FEATURES: [usize; 4] = [4, 8, 16, 32];
pub const NUM_BLOCKS: usize = 7;
pub const DSI_FEATURES: usize = 2;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DenseOp {
    Fc,
    Dp,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SparseOp {
    Efc,
    Identity,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Interaction {
    None,
    Dsi,
    Fm,
}

#[derive(Clone, Debug, PartialEq)]
pub struct Block {
    pub dense_op: DenseOp,
    pub dense_dim: usize,
    pub dense_wbits: usize,
    pub sparse_op: SparseOp,
    pub sparse_features: usize,
    pub sparse_wbits: usize,
    pub interaction: Interaction,
    pub inter_wbits: usize,
    /// input sources: 0 = raw inputs, j≥1 = block j's output
    pub dense_in: Vec<usize>,
    pub sparse_in: Vec<usize>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct Genome {
    pub name: String,
    pub dataset: String,
    pub d_emb: usize,
    pub blocks: Vec<Block>,
    pub final_wbits: usize,
    pub pim: PimConfig,
}

/// Per-block inferred IO shapes (mirror of arch/model.py::infer_shapes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BlockShape {
    /// dense input dim (after concat)
    pub din: usize,
    /// dense output dim
    pub dout: usize,
    /// sparse input feature count (after concat)
    pub nin: usize,
    /// sparse output feature count (incl. DSI extension)
    pub nout: usize,
}

impl Genome {
    pub fn validate(&self) -> crate::Result<()> {
        crate::ensure!(SPARSE_DIMS.contains(&self.d_emb), "d_emb {}", self.d_emb);
        crate::ensure!(!self.blocks.is_empty(), "no blocks");
        crate::ensure!(self.pim.feasible(), "PIM genome violates the ADC rule");
        crate::ensure!(WEIGHT_BITS.contains(&self.final_wbits), "final_wbits");
        for (i, b) in self.blocks.iter().enumerate() {
            crate::ensure!(DENSE_DIMS.contains(&b.dense_dim), "block {i} dense_dim");
            crate::ensure!(
                SPARSE_FEATURES.contains(&b.sparse_features),
                "block {i} sparse_features"
            );
            for w in [b.dense_wbits, b.sparse_wbits, b.inter_wbits] {
                crate::ensure!(WEIGHT_BITS.contains(&w), "block {i} wbits {w}");
            }
            crate::ensure!(
                !b.dense_in.is_empty() && b.dense_in.iter().all(|&j| j <= i),
                "block {i} dense_in"
            );
            crate::ensure!(
                !b.sparse_in.is_empty() && b.sparse_in.iter().all(|&j| j <= i),
                "block {i} sparse_in"
            );
        }
        Ok(())
    }

    /// DP engine stack height: ⌈√(2·dim_d)⌉ (paper §3.2).
    pub fn dp_rows(dense_dim: usize) -> usize {
        (2.0 * dense_dim as f64).sqrt().ceil() as usize
    }

    /// Mirror of python infer_shapes (shape semantics contract).
    pub fn shapes(&self) -> crate::Result<Vec<BlockShape>> {
        let prof = profile(&self.dataset)?;
        let mut dense_dims = vec![prof.n_dense.max(1)];
        let mut sparse_ns = vec![prof.n_sparse()];
        let mut out = Vec::with_capacity(self.blocks.len());
        for b in &self.blocks {
            let din = b.dense_in.iter().map(|&j| dense_dims[j]).sum();
            let nin: usize = b.sparse_in.iter().map(|&j| sparse_ns[j]).sum();
            let mut nout = match b.sparse_op {
                SparseOp::Efc => b.sparse_features,
                SparseOp::Identity => nin,
            };
            if b.interaction == Interaction::Dsi {
                nout += DSI_FEATURES;
            }
            out.push(BlockShape {
                din,
                dout: b.dense_dim,
                nin,
                nout,
            });
            dense_dims.push(b.dense_dim);
            sparse_ns.push(nout);
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // JSON (byte-compatible with arch.py)
    // ------------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let blocks = self
            .blocks
            .iter()
            .map(|b| {
                Json::from_pairs(vec![
                    ("dense_op", Json::Str(match b.dense_op {
                        DenseOp::Fc => "fc".into(),
                        DenseOp::Dp => "dp".into(),
                    })),
                    ("dense_dim", Json::Num(b.dense_dim as f64)),
                    ("dense_wbits", Json::Num(b.dense_wbits as f64)),
                    ("sparse_op", Json::Str(match b.sparse_op {
                        SparseOp::Efc => "efc".into(),
                        SparseOp::Identity => "identity".into(),
                    })),
                    ("sparse_features", Json::Num(b.sparse_features as f64)),
                    ("sparse_wbits", Json::Num(b.sparse_wbits as f64)),
                    ("interaction", Json::Str(match b.interaction {
                        Interaction::None => "none".into(),
                        Interaction::Dsi => "dsi".into(),
                        Interaction::Fm => "fm".into(),
                    })),
                    ("inter_wbits", Json::Num(b.inter_wbits as f64)),
                    ("dense_in", Json::arr_usize(&b.dense_in)),
                    ("sparse_in", Json::arr_usize(&b.sparse_in)),
                ])
            })
            .collect();
        Json::from_pairs(vec![
            ("name", Json::Str(self.name.clone())),
            ("dataset", Json::Str(self.dataset.clone())),
            ("d_emb", Json::Num(self.d_emb as f64)),
            ("blocks", Json::Arr(blocks)),
            ("final_wbits", Json::Num(self.final_wbits as f64)),
            ("pim", self.pim.to_json()),
        ])
    }

    pub fn from_json(j: &Json) -> crate::Result<Genome> {
        let blocks = j
            .req_arr("blocks")?
            .iter()
            .map(|b| -> crate::Result<Block> {
                Ok(Block {
                    dense_op: match b.req_str("dense_op")? {
                        "fc" => DenseOp::Fc,
                        "dp" => DenseOp::Dp,
                        o => crate::bail!("dense_op {o}"),
                    },
                    dense_dim: b.req_usize("dense_dim")?,
                    dense_wbits: b.req_usize("dense_wbits")?,
                    sparse_op: match b.req_str("sparse_op")? {
                        "efc" => SparseOp::Efc,
                        "identity" => SparseOp::Identity,
                        o => crate::bail!("sparse_op {o}"),
                    },
                    sparse_features: b.req_usize("sparse_features")?,
                    sparse_wbits: b.req_usize("sparse_wbits")?,
                    interaction: match b.req_str("interaction")? {
                        "none" => Interaction::None,
                        "dsi" => Interaction::Dsi,
                        "fm" => Interaction::Fm,
                        o => crate::bail!("interaction {o}"),
                    },
                    inter_wbits: b.req_usize("inter_wbits")?,
                    dense_in: b
                        .req_arr("dense_in")?
                        .iter()
                        .map(|v| v.as_usize().unwrap())
                        .collect(),
                    sparse_in: b
                        .req_arr("sparse_in")?
                        .iter()
                        .map(|v| v.as_usize().unwrap())
                        .collect(),
                })
            })
            .collect::<crate::Result<Vec<_>>>()?;
        let g = Genome {
            name: j.req_str("name")?.to_string(),
            dataset: j.req_str("dataset")?.to_string(),
            d_emb: j.req_usize("d_emb")?,
            blocks,
            final_wbits: j.req_usize("final_wbits")?,
            pim: PimConfig::from_json(
                j.get("pim").ok_or_else(|| crate::err!("missing pim"))?,
            )?,
        };
        g.validate()?;
        Ok(g)
    }

    pub fn load(path: &std::path::Path) -> crate::Result<Genome> {
        Genome::from_json(&Json::read_file(path)?)
    }

    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        self.to_json().write_file(path)
    }

    /// Stable content hash (population dedup).
    pub fn hash(&self) -> u64 {
        Self::fnv(&self.to_json().to_string_compact())
    }

    /// Structural hash: identical to [`Genome::hash`] except the `name`
    /// field is blanked in the canonical JSON (replaced in place, so key
    /// order is preserved), making renamed copies of one architecture
    /// collide intentionally — the evaluation-cache key
    /// ([`crate::mapping::genome_eval_key`]). Avoids deep-cloning the
    /// genome on the search hot loop.
    pub fn structural_hash(&self) -> u64 {
        let mut j = self.to_json();
        j.set("name", Json::Str(String::new()));
        Self::fnv(&j.to_string_compact())
    }

    /// FNV-1a over the canonical JSON text (shared by both hashes).
    fn fnv(s: &str) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in s.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        h
    }
}

/// Built-in reference genome mirroring arch.py::autorac_best (used by
/// tests and as the search's warm-start).
pub fn autorac_best(dataset: &str) -> Genome {
    let b = |dense_op, dense_dim, dense_wbits, sparse_op, sparse_features,
             sparse_wbits, interaction, inter_wbits, dense_in: &[usize],
             sparse_in: &[usize]| Block {
        dense_op,
        dense_dim,
        dense_wbits,
        sparse_op,
        sparse_features,
        sparse_wbits,
        interaction,
        inter_wbits,
        dense_in: dense_in.to_vec(),
        sparse_in: sparse_in.to_vec(),
    };
    use DenseOp::*;
    use Interaction::*;
    use SparseOp::*;
    Genome {
        name: format!("autorac-{dataset}"),
        dataset: dataset.to_string(),
        d_emb: 32,
        blocks: vec![
            b(Fc, 256, 8, Efc, 16, 8, Fm, 8, &[0], &[0]),
            b(Fc, 128, 4, Efc, 16, 8, None, 8, &[1], &[1]),
            b(Dp, 128, 4, Efc, 8, 8, None, 4, &[1, 2], &[2]),
            b(Fc, 128, 4, Identity, 8, 8, Fm, 4, &[3], &[3]),
            b(Fc, 128, 4, Efc, 8, 8, Dsi, 4, &[3, 4], &[4]),
            b(Dp, 64, 8, Identity, 8, 8, Fm, 8, &[5], &[5]),
            b(Fc, 128, 8, Identity, 8, 8, None, 8, &[5, 6], &[6]),
        ],
        final_wbits: 8,
        pim: PimConfig {
            xbar: 64,
            dac_bits: 1,
            cell_bits: 2,
            adc_bits: 8,
            ..PimConfig::default()
        },
    }
}

/// Mirror of arch.py::nasrec_like.
pub fn nasrec_like(dataset: &str) -> Genome {
    use DenseOp::*;
    use Interaction::*;
    use SparseOp::*;
    let b = |dense_op, dense_dim, sparse_op, sparse_features, interaction,
             dense_in: &[usize], sparse_in: &[usize]| Block {
        dense_op,
        dense_dim,
        dense_wbits: 8,
        sparse_op,
        sparse_features,
        sparse_wbits: 8,
        interaction,
        inter_wbits: 8,
        dense_in: dense_in.to_vec(),
        sparse_in: sparse_in.to_vec(),
    };
    Genome {
        name: format!("nasrec-{dataset}"),
        dataset: dataset.to_string(),
        d_emb: 32,
        blocks: vec![
            b(Fc, 256, Efc, 16, Fm, &[0], &[0]),
            b(Dp, 128, Efc, 16, None, &[1], &[1]),
            b(Fc, 256, Efc, 8, Dsi, &[2], &[2]),
            b(Fc, 128, Identity, 8, Fm, &[2, 3], &[3]),
            b(Fc, 128, Efc, 8, None, &[4], &[4]),
            b(Dp, 64, Identity, 8, Fm, &[5], &[5]),
            b(Fc, 64, Identity, 8, None, &[5, 6], &[6]),
        ],
        final_wbits: 8,
        pim: PimConfig {
            xbar: 64,
            dac_bits: 1,
            cell_bits: 1,
            adc_bits: 8,
            ..PimConfig::default()
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_genomes_validate() {
        for ds in ["criteo", "avazu", "kdd"] {
            autorac_best(ds).validate().unwrap();
            nasrec_like(ds).validate().unwrap();
        }
    }

    #[test]
    fn shapes_mirror_python_semantics() {
        let g = autorac_best("criteo");
        let sh = g.shapes().unwrap();
        // block0: raw dense 13 → 256; raw sparse 26 → efc 16
        assert_eq!(sh[0], BlockShape { din: 13, dout: 256, nin: 26, nout: 16 });
        // block4 has DSI: nout = sparse_features + DSI_FEATURES
        assert_eq!(sh[4].nout, 8 + DSI_FEATURES);
        // block6 concatenates blocks 5 and 6 dense outputs (64 + 128)
        assert_eq!(sh[6].din, 64 + 128);
    }

    #[test]
    fn json_roundtrip_preserves_genome() {
        let g = autorac_best("avazu");
        let j = g.to_json();
        let g2 = Genome::from_json(&j).unwrap();
        assert_eq!(g, g2);
        assert_eq!(g.hash(), g2.hash());
    }

    #[test]
    fn invalid_genomes_are_rejected() {
        let mut g = autorac_best("criteo");
        g.d_emb = 100;
        assert!(g.validate().is_err());
        let mut g2 = autorac_best("criteo");
        g2.blocks[0].dense_in = vec![5]; // forward reference
        assert!(g2.validate().is_err());
        let mut g3 = autorac_best("criteo");
        g3.pim.dac_bits = 2;
        g3.pim.cell_bits = 2; // 64·3·3 = 576 > 255
        assert!(g3.validate().is_err());
    }

    #[test]
    fn dp_rows_formula() {
        assert_eq!(Genome::dp_rows(128), 16);
        assert_eq!(Genome::dp_rows(64), 12); // ⌈√128⌉ = 12
    }

    #[test]
    fn hash_distinguishes_genomes() {
        let a = autorac_best("criteo");
        let mut b = a.clone();
        b.blocks[3].dense_wbits = 8;
        assert_ne!(a.hash(), b.hash());
    }
}
