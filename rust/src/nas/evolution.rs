//! Regularized-evolution co-search — Algorithm 1, line for line.
//!
//! criterion = test_loss + Σᵢ λᵢ · metricᵢ / targetᵢ over
//! metrics = [1/throughput, area, power] from the behavioral simulator
//! (smart mapping), with test_loss from the calibrated surrogate.
//!
//! [`Search`] is the *serial reference* (one shared RNG stream, exactly
//! the paper's pseudocode); production entry points run
//! [`super::parallel::ParallelSearch`], which evaluates children
//! concurrently, memoizes by structural genome hash, and maintains a
//! Pareto archive — while sharing this module's [`SearchConfig`] /
//! [`Individual`] / [`SearchTrace`] types (DESIGN.md §7.6).

use super::accuracy::Surrogate;
use super::genome::Genome;
use super::space::{mutate, random_genome};
use crate::mapping::{map_genome, MapStyle};
use crate::pim::TechParams;
use crate::sim::{simulate, SimReport, Workload};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SearchConfig {
    pub dataset: String,
    pub population: usize,
    pub generations: usize,
    pub children_per_gen: usize,
    pub mutations_per_child: usize,
    /// tournament size for Sample_and_select
    pub sample_size: usize,
    /// λ weights for [1/throughput, area, power]
    pub lambdas: [f64; 3],
    pub seed: u64,
    /// requests per candidate simulation
    pub sim_requests: usize,
    /// evaluation worker threads for [`super::parallel::ParallelSearch`]
    /// (≤ 1 evaluates inline on the caller's thread; the trace is
    /// bit-identical either way — pinned by `tests/search_determinism.rs`)
    pub workers: usize,
    /// bounded capacity of the [`super::pareto::ParetoArchive`] kept
    /// alongside the scalar criterion (clamped to ≥ 2)
    pub pareto_capacity: usize,
    /// memoize evaluations by structural genome hash
    /// ([`crate::mapping::genome_eval_key`]); results are bit-identical
    /// with the cache off, it only skips redundant simulator runs
    pub cache: bool,
}

impl SearchConfig {
    /// Default worker count for throughput-oriented entry points (the
    /// benches and the co-design example): every hardware thread. The
    /// result is bit-identical for any worker count, so this is purely
    /// a wall-clock choice.
    pub fn all_cores() -> usize {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    }
}

impl Default for SearchConfig {
    fn default() -> Self {
        SearchConfig {
            dataset: "criteo".to_string(),
            population: 32,
            generations: 240,
            children_per_gen: 8,
            mutations_per_child: 2,
            sample_size: 8,
            lambdas: [0.05, 0.05, 0.05],
            seed: 20_250_630,
            sim_requests: 48,
            workers: 1,
            pareto_capacity: 64,
            cache: true,
        }
    }
}

/// One evaluated candidate.
#[derive(Clone, Debug)]
pub struct Individual {
    pub genome: Genome,
    pub test_loss: f64,
    pub metrics: [f64; 3],
    pub criterion: f64,
    pub generation: usize,
}

/// Search trace (drives Figure 5).
#[derive(Clone, Debug, Default)]
pub struct SearchTrace {
    /// best criterion after each generation
    pub best_criterion: Vec<f64>,
    /// population-mean criterion after each generation
    pub mean_criterion: Vec<f64>,
    pub evaluations: usize,
}

/// The scalar criterion (Algorithm 1 line 11):
/// `test_loss + Σᵢ λᵢ · metricᵢ / targetᵢ`. One definition shared by the
/// serial reference and the parallel engine, so the two can never
/// diverge in the arithmetic their comparison tests rely on.
pub fn criterion(
    lambdas: &[f64; 3],
    targets: &[f64; 3],
    test_loss: f64,
    metrics: &[f64; 3],
) -> f64 {
    let hw_term: f64 = (0..3)
        .map(|i| lambdas[i] * metrics[i] / targets[i])
        .sum();
    test_loss + hw_term
}

impl SearchTrace {
    /// Fold one generation's population into the trace (best + mean
    /// criterion). Shared bookkeeping for both engines — the fold order
    /// is the population order, so callers must present a
    /// deterministically-ordered population.
    pub fn record(&mut self, population: &[Individual]) {
        let best = population
            .iter()
            .map(|i| i.criterion)
            .fold(f64::INFINITY, f64::min);
        let mean = population.iter().map(|i| i.criterion).sum::<f64>()
            / population.len().max(1) as f64;
        self.best_criterion.push(best);
        self.mean_criterion.push(mean);
    }

    /// Figure 5's y-axis: percentage drop of the best criterion relative
    /// to generation 0 (lower is better). An empty trace yields an empty
    /// Vec; the explicit early return replaces a silent `unwrap_or(1.0)`
    /// placeholder base so the contract is visible and test-pinned
    /// rather than an accident of mapping over an empty Vec.
    pub fn pct_drop(&self) -> Vec<f64> {
        let Some(&base) = self.best_criterion.first() else {
            return Vec::new();
        };
        self.best_criterion
            .iter()
            .map(|c| 100.0 * (c - base) / base)
            .collect()
    }
}

pub struct Search {
    pub cfg: SearchConfig,
    tech: TechParams,
    surrogate: Surrogate,
    /// design targets [1/throughput, area, power] (Algorithm 1 inputs)
    pub targets: [f64; 3],
    rng: Rng,
    pub population: Vec<Individual>,
    pub trace: SearchTrace,
    generation: usize,
}

impl Search {
    /// Targets default to the metrics of the hand-crafted NASRec design
    /// — "reach or beat the manual design on every axis".
    pub fn new(cfg: SearchConfig, surrogate: Surrogate) -> crate::Result<Search> {
        let tech = TechParams::default();
        let reference = super::genome::nasrec_like(&cfg.dataset);
        let r = Self::sim_genome(&reference, &tech, cfg.sim_requests)?;
        let targets = [1.0 / r.throughput_rps, r.area_mm2, r.power_mw];
        Ok(Search {
            rng: Rng::new(cfg.seed),
            cfg,
            tech,
            surrogate,
            targets,
            population: Vec::new(),
            trace: SearchTrace::default(),
            generation: 0,
        })
    }

    fn sim_genome(
        g: &Genome,
        tech: &TechParams,
        requests: usize,
    ) -> crate::Result<SimReport> {
        let mapped = map_genome(g, tech, MapStyle::Smart)?;
        Ok(simulate(
            &mapped,
            None,
            &Workload {
                n_requests: requests,
                ..Workload::default()
            },
        ))
    }

    /// Evaluate a genome → Individual (Algorithm 1 lines 9–11).
    pub fn evaluate(&mut self, genome: Genome) -> crate::Result<Individual> {
        let test_loss = self.surrogate.logloss(&genome);
        let r = Self::sim_genome(&genome, &self.tech, self.cfg.sim_requests)?;
        let metrics = [1.0 / r.throughput_rps, r.area_mm2, r.power_mw];
        self.trace.evaluations += 1;
        Ok(Individual {
            criterion: criterion(&self.cfg.lambdas, &self.targets, test_loss, &metrics),
            genome,
            test_loss,
            metrics,
            generation: self.generation,
        })
    }

    /// Line 1: all_populations ← random_search(supernet).
    pub fn init_population(&mut self) -> crate::Result<()> {
        let mut rng = self.rng.substream("init");
        for i in 0..self.cfg.population {
            let g = random_genome(&mut rng, &self.cfg.dataset.clone(), &format!("init{i}"));
            let ind = self.evaluate(g)?;
            self.population.push(ind);
        }
        self.record_generation();
        Ok(())
    }

    fn record_generation(&mut self) {
        self.trace.record(&self.population);
    }

    /// Lines 3–15: one generation.
    pub fn step(&mut self) -> crate::Result<()> {
        self.generation += 1;
        // Sample_and_select: tournament of `sample_size`, best criterion.
        let mut rng = self.rng.substream(&format!("gen/{}", self.generation));
        let parent_idx = (0..self.cfg.sample_size)
            .map(|_| rng.below(self.population.len() as u64) as usize)
            .min_by(|&a, &b| {
                self.population[a]
                    .criterion
                    .partial_cmp(&self.population[b].criterion)
                    .unwrap()
            })
            .unwrap();
        let parent = self.population[parent_idx].genome.clone();
        for c in 0..self.cfg.children_per_gen {
            let mut choice = parent.clone();
            for _ in 0..self.cfg.mutations_per_child {
                choice = mutate(&choice, &mut rng);
            }
            choice.name = format!("g{}c{}", self.generation, c);
            let ind = self.evaluate(choice)?;
            self.population.push(ind);
        }
        // sort by criterion; remove last num_children entries (line 14–15)
        self.population
            .sort_by(|a, b| a.criterion.partial_cmp(&b.criterion).unwrap());
        self.population.truncate(self.cfg.population);
        self.record_generation();
        Ok(())
    }

    /// Run the full search; returns the best individual.
    pub fn run(&mut self) -> crate::Result<Individual> {
        if self.population.is_empty() {
            self.init_population()?;
        }
        for _ in 0..self.cfg.generations {
            self.step()?;
        }
        Ok(self.best().clone())
    }

    pub fn best(&self) -> &Individual {
        self.population
            .iter()
            .min_by(|a, b| a.criterion.partial_cmp(&b.criterion).unwrap())
            .expect("non-empty population")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> SearchConfig {
        SearchConfig {
            generations: 12,
            population: 12,
            children_per_gen: 4,
            sample_size: 4,
            sim_requests: 16,
            ..SearchConfig::default()
        }
    }

    #[test]
    fn search_improves_criterion() {
        let mut s = Search::new(quick_cfg(), Surrogate::prior()).unwrap();
        let best = s.run().unwrap();
        let first = s.trace.best_criterion[0];
        assert!(
            best.criterion < first,
            "no improvement: {} -> {}",
            first,
            best.criterion
        );
        // population invariant (Algorithm 1 line 15)
        assert_eq!(s.population.len(), s.cfg.population);
    }

    #[test]
    fn best_criterion_is_monotone_nonincreasing() {
        let mut s = Search::new(quick_cfg(), Surrogate::prior()).unwrap();
        s.run().unwrap();
        for w in s.trace.best_criterion.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "best went up: {:?}", w);
        }
    }

    #[test]
    fn search_is_deterministic_per_seed() {
        let run = |seed| {
            let mut cfg = quick_cfg();
            cfg.seed = seed;
            let mut s = Search::new(cfg, Surrogate::prior()).unwrap();
            s.run().unwrap().genome.hash()
        };
        assert_eq!(run(1), run(1));
        assert_ne!(run(1), run(2));
    }

    #[test]
    fn pct_drop_of_empty_trace_is_empty() {
        // pins the empty-trace contract: previously this held only by
        // accident (mapping over an empty Vec past an unwrap_or(1.0)
        // placeholder base); now it is an explicit early return
        assert!(SearchTrace::default().pct_drop().is_empty());
        let one = SearchTrace {
            best_criterion: vec![0.5],
            mean_criterion: vec![0.5],
            evaluations: 1,
        };
        assert_eq!(one.pct_drop(), vec![0.0]);
    }

    #[test]
    fn pct_drop_starts_at_zero_and_decreases() {
        let mut s = Search::new(quick_cfg(), Surrogate::prior()).unwrap();
        s.run().unwrap();
        let drop = s.trace.pct_drop();
        assert_eq!(drop[0], 0.0);
        assert!(*drop.last().unwrap() <= 0.0);
    }

    #[test]
    fn all_evaluated_genomes_are_feasible() {
        let mut s = Search::new(quick_cfg(), Surrogate::prior()).unwrap();
        s.run().unwrap();
        for ind in &s.population {
            ind.genome.validate().unwrap();
        }
    }
}
