//! Bounded Pareto archive over the paper's four co-optimized objectives
//! (S21): `[test_loss, 1/throughput, area, power]`, all minimized.
//!
//! Algorithm 1 collapses the objectives into one scalar criterion; the
//! archive is maintained *alongside* that scalar path, so the search
//! still selects and evicts by criterion while the front records every
//! trade-off the run discovered. Invariants (pinned by the tests below
//! and consumed by `tests/search_determinism.rs`):
//!
//! * no archived point dominates another (mutual non-domination);
//! * offering a dominated (or duplicate) point is a no-op;
//! * capacity eviction never removes the knee point nor the
//!   best-scalar-criterion point — with all-positive λ weights the
//!   criterion is strictly increasing in every objective, so the global
//!   scalar winner is never dominated and therefore stays on the front.

use super::genome::Genome;

/// The co-optimized objective count: test_loss, 1/throughput, area, power.
pub const N_OBJECTIVES: usize = 4;

/// `a` Pareto-dominates `b`: no worse on every objective, strictly
/// better on at least one (all objectives minimized).
pub fn dominates(a: &[f64; N_OBJECTIVES], b: &[f64; N_OBJECTIVES]) -> bool {
    let mut strictly = false;
    for i in 0..N_OBJECTIVES {
        if a[i] > b[i] {
            return false;
        }
        if a[i] < b[i] {
            strictly = true;
        }
    }
    strictly
}

/// One archived candidate: its objective vector, the scalar criterion
/// the search selected by, and the genome itself so the knee point can
/// be re-mapped / re-simulated without a second search.
#[derive(Clone, Debug)]
pub struct ParetoPoint {
    pub objectives: [f64; N_OBJECTIVES],
    pub criterion: f64,
    pub generation: usize,
    pub genome: Genome,
}

/// Dominance-pruned, capacity-bounded archive.
pub struct ParetoArchive {
    capacity: usize,
    points: Vec<ParetoPoint>,
    /// lifetime counters (offers = inserted + rejected)
    pub inserted: usize,
    pub rejected: usize,
    pub evicted: usize,
}

impl ParetoArchive {
    /// `capacity` is clamped to ≥ 2 so the two protected points (knee
    /// and scalar winner) always fit.
    pub fn new(capacity: usize) -> ParetoArchive {
        ParetoArchive {
            capacity: capacity.max(2),
            points: Vec::new(),
            inserted: 0,
            rejected: 0,
            evicted: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The current front, in insertion order.
    pub fn points(&self) -> &[ParetoPoint] {
        &self.points
    }

    /// Offer a candidate. Returns `true` if it entered the archive.
    /// Deterministic: outcome depends only on the offer sequence.
    pub fn offer(&mut self, p: ParetoPoint) -> bool {
        // Dominated (or exactly duplicated) by an archived point → no-op.
        if self
            .points
            .iter()
            .any(|q| dominates(&q.objectives, &p.objectives) || q.objectives == p.objectives)
        {
            self.rejected += 1;
            return false;
        }
        // Entering point prunes everything it dominates.
        let before = self.points.len();
        self.points.retain(|q| !dominates(&p.objectives, &q.objectives));
        self.evicted += before - self.points.len();
        self.points.push(p);
        self.inserted += 1;
        if self.points.len() > self.capacity {
            self.evict_for_capacity();
        }
        true
    }

    /// Knee point: the archived point closest (L2) to the ideal corner
    /// after min–max normalizing each objective over the front. Ties
    /// resolve to the earliest-inserted point.
    pub fn knee(&self) -> Option<&ParetoPoint> {
        self.knee_index().map(|i| &self.points[i])
    }

    /// The archived point with the lowest scalar criterion.
    pub fn best_criterion(&self) -> Option<&ParetoPoint> {
        self.best_criterion_index().map(|i| &self.points[i])
    }

    fn knee_index(&self) -> Option<usize> {
        let (lo, hi) = self.bounds()?;
        let mut best = 0usize;
        let mut best_d = f64::INFINITY;
        for (i, q) in self.points.iter().enumerate() {
            let d = norm_dist(&q.objectives, &lo, &hi);
            if d < best_d {
                best_d = d;
                best = i;
            }
        }
        Some(best)
    }

    fn best_criterion_index(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, q) in self.points.iter().enumerate() {
            if best.map_or(true, |b| q.criterion < self.points[b].criterion) {
                best = Some(i);
            }
        }
        best
    }

    /// Per-objective (min, max) over the archive.
    fn bounds(&self) -> Option<([f64; N_OBJECTIVES], [f64; N_OBJECTIVES])> {
        if self.points.is_empty() {
            return None;
        }
        let mut lo = [f64::INFINITY; N_OBJECTIVES];
        let mut hi = [f64::NEG_INFINITY; N_OBJECTIVES];
        for q in &self.points {
            for i in 0..N_OBJECTIVES {
                lo[i] = lo[i].min(q.objectives[i]);
                hi[i] = hi[i].max(q.objectives[i]);
            }
        }
        Some((lo, hi))
    }

    /// Over capacity: drop the point farthest from the normalized ideal
    /// corner, never the knee nor the scalar-criterion winner. Called
    /// only when `len > capacity ≥ 2`, so an unprotected point exists.
    fn evict_for_capacity(&mut self) {
        let knee = self.knee_index();
        let best = self.best_criterion_index();
        let (lo, hi) = self.bounds().expect("non-empty archive");
        let mut victim: Option<usize> = None;
        let mut victim_d = f64::NEG_INFINITY;
        for (i, q) in self.points.iter().enumerate() {
            if Some(i) == knee || Some(i) == best {
                continue;
            }
            let d = norm_dist(&q.objectives, &lo, &hi);
            if d > victim_d {
                victim_d = d;
                victim = Some(i);
            }
        }
        if let Some(i) = victim {
            self.points.remove(i);
            self.evicted += 1;
        }
    }
}

/// L2 distance to the ideal (all-minima) corner in min–max-normalized
/// objective space; degenerate axes (max == min) contribute 0.
fn norm_dist(
    obj: &[f64; N_OBJECTIVES],
    lo: &[f64; N_OBJECTIVES],
    hi: &[f64; N_OBJECTIVES],
) -> f64 {
    let mut s = 0.0;
    for i in 0..N_OBJECTIVES {
        let span = hi[i] - lo[i];
        if span > 0.0 {
            let z = (obj[i] - lo[i]) / span;
            s += z * z;
        }
    }
    s.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::genome::autorac_best;
    use crate::util::qcheck::qcheck;
    use crate::util::rng::Rng;

    fn point(objectives: [f64; N_OBJECTIVES], criterion: f64) -> ParetoPoint {
        ParetoPoint {
            objectives,
            criterion,
            generation: 0,
            genome: autorac_best("criteo"),
        }
    }

    /// Positive-weight scalarization — strictly increasing in every
    /// objective, like the search criterion with all-positive λ.
    fn scalar(o: &[f64; N_OBJECTIVES]) -> f64 {
        o[0] + 0.05 * o[1] + 0.05 * o[2] + 0.05 * o[3]
    }

    fn random_objectives(rng: &mut Rng) -> [f64; N_OBJECTIVES] {
        // coarse grid so duplicates and dominance both actually occur
        let mut o = [0.0; N_OBJECTIVES];
        for v in o.iter_mut() {
            *v = rng.range(0, 9) as f64 / 8.0;
        }
        o
    }

    fn assert_mutually_nondominated(a: &ParetoArchive) {
        let pts = a.points();
        for i in 0..pts.len() {
            for j in 0..pts.len() {
                if i != j {
                    assert!(
                        !dominates(&pts[i].objectives, &pts[j].objectives),
                        "archived {i} dominates archived {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn dominance_is_a_strict_partial_order() {
        let a = [1.0, 1.0, 1.0, 1.0];
        let b = [1.0, 1.0, 1.0, 2.0];
        let c = [2.0, 0.5, 1.0, 1.0];
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        assert!(!dominates(&a, &a), "irreflexive");
        assert!(!dominates(&a, &c) && !dominates(&c, &a), "incomparable");
    }

    #[test]
    fn dominated_insertion_is_a_noop() {
        let mut ar = ParetoArchive::new(8);
        assert!(ar.offer(point([1.0, 1.0, 1.0, 1.0], 1.15)));
        assert!(!ar.offer(point([1.0, 1.0, 1.0, 1.0], 1.15)), "duplicate");
        assert!(!ar.offer(point([2.0, 1.0, 1.0, 1.0], 2.15)), "dominated");
        assert_eq!(ar.len(), 1);
        assert_eq!(ar.rejected, 2);
        // a dominating point replaces what it dominates
        assert!(ar.offer(point([0.5, 1.0, 1.0, 1.0], 0.65)));
        assert_eq!(ar.len(), 1);
        assert_eq!(ar.points()[0].objectives[0], 0.5);
    }

    #[test]
    fn archive_is_always_mutually_nondominated() {
        qcheck(60, |g| {
            let mut ar = ParetoArchive::new(*g.choose(&[2usize, 4, 8]));
            let n = g.usize(1, 60);
            let rng = g.rng();
            for k in 0..n {
                let o = random_objectives(rng);
                ar.offer(ParetoPoint {
                    objectives: o,
                    criterion: scalar(&o),
                    generation: k,
                    genome: autorac_best("criteo"),
                });
                let pts = ar.points();
                for i in 0..pts.len() {
                    for j in 0..pts.len() {
                        if i != j && dominates(&pts[i].objectives, &pts[j].objectives) {
                            return Err(format!(
                                "after offer {k}: archived point {i} dominates {j}"
                            ));
                        }
                    }
                }
                crate::prop_assert!(ar.len() <= ar.capacity(), "over capacity");
            }
            Ok(())
        });
    }

    #[test]
    fn capacity_eviction_keeps_the_knee_point() {
        // capacity 3, then a 4th mutually-non-dominated point forces an
        // eviction. Post-insert normalized distances to the ideal corner:
        //   A [0,1,1,1]           → 1.73   (best criterion — protected)
        //   B [1,0,0,0]           → 1.00
        //   K [.4,.4,.4,.4]       → 0.80   (knee — protected)
        //   D [.9,.05,.95,.95]    → 1.62   (farthest unprotected → victim)
        let mut ar = ParetoArchive::new(3);
        let a = [0.0, 1.0, 1.0, 1.0];
        let b = [1.0, 0.0, 0.0, 0.0];
        let k = [0.4, 0.4, 0.4, 0.4];
        let d = [0.9, 0.05, 0.95, 0.95];
        for o in [a, b, k] {
            assert!(ar.offer(point(o, scalar(&o))));
        }
        assert_eq!(ar.knee().unwrap().objectives, k);
        assert!(ar.offer(point(d, scalar(&d))));
        assert_eq!(ar.len(), 3, "eviction brought the archive back to capacity");
        let has = |o: [f64; N_OBJECTIVES]| ar.points().iter().any(|p| p.objectives == o);
        assert!(has(k), "knee point was capacity-evicted");
        assert!(has(a), "best-criterion point was capacity-evicted");
        assert!(!has(d), "the farthest unprotected point is the victim");
        assert_eq!(ar.evicted, 1);
        assert_mutually_nondominated(&ar);
    }

    #[test]
    fn scalar_winner_stays_on_the_front() {
        qcheck(40, |g| {
            let mut ar = ParetoArchive::new(4);
            let n = g.usize(1, 80);
            let rng = g.rng();
            let mut best_scalar = f64::INFINITY;
            let mut best_obj = [0.0; N_OBJECTIVES];
            for k in 0..n {
                let o = random_objectives(rng);
                let c = scalar(&o);
                ar.offer(ParetoPoint {
                    objectives: o,
                    criterion: c,
                    generation: k,
                    genome: autorac_best("criteo"),
                });
                if c < best_scalar {
                    best_scalar = c;
                    best_obj = o;
                }
                // the global scalar winner is on the front, or dominated
                // only by a front member (ties on the scalar can be
                // mutually non-dominating, so equality is not enough)
                let on_front = ar.points().iter().any(|p| p.objectives == best_obj);
                let dominated_by_front = ar
                    .points()
                    .iter()
                    .any(|p| dominates(&p.objectives, &best_obj));
                crate::prop_assert!(
                    on_front || dominated_by_front,
                    "scalar winner {best_obj:?} lost from the front at offer {k}"
                );
            }
            if !ar.is_empty() {
                let archived_best = ar.best_criterion().unwrap().criterion;
                crate::prop_assert!(
                    (archived_best - best_scalar).abs() < 1e-12,
                    "archived best criterion {archived_best} != global {best_scalar}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn knee_is_the_normalized_closest_point() {
        let mut ar = ParetoArchive::new(8);
        // a balanced point and two extremists; knee must be the balance
        for (o, c) in [
            ([0.0, 1.0, 1.0, 1.0], 0.15),
            ([1.0, 0.0, 0.0, 0.0], 1.0),
            ([0.4, 0.4, 0.4, 0.4], 0.46),
        ] {
            assert!(ar.offer(point(o, c)));
        }
        assert_mutually_nondominated(&ar);
        let knee = ar.knee().unwrap();
        assert_eq!(knee.objectives, [0.4, 0.4, 0.4, 0.4]);
    }

    #[test]
    fn counters_balance() {
        let mut rng = Rng::new(9);
        let mut ar = ParetoArchive::new(4);
        let mut offers = 0usize;
        for k in 0..300 {
            let o = random_objectives(&mut rng);
            ar.offer(ParetoPoint {
                objectives: o,
                criterion: scalar(&o),
                generation: k,
                genome: autorac_best("criteo"),
            });
            offers += 1;
        }
        assert_eq!(ar.inserted + ar.rejected, offers);
        assert_eq!(ar.inserted - ar.evicted, ar.len());
    }
}
