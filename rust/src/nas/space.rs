//! Design-space operations: random sampling and the mutation set of
//! Algorithm 1 ("swapping dense/sparse operators, modifying dense/sparse
//! dimensions, adjusting block-to-block connections, or introducing
//! dense-sparse interaction layers", plus the PIM-side mutations
//! "toggling among different ADC resolutions, DAC options, memristor
//! precisions, and crossbar sizes").

use super::genome::{
    Block, DenseOp, Genome, Interaction, SparseOp, DENSE_DIMS, NUM_BLOCKS,
    SPARSE_DIMS, SPARSE_FEATURES, WEIGHT_BITS,
};
use crate::pim::config::{ADC_OPTIONS, CELL_OPTIONS, DAC_OPTIONS, XBAR_SIZES};
use crate::pim::PimConfig;
use crate::util::rng::Rng;

/// Uniform random genome (mirrors arch.py::random_genome; dense dims are
/// capped at 512 to keep calibration-comparable models).
pub fn random_genome(rng: &mut Rng, dataset: &str, name: &str) -> Genome {
    let mut blocks = Vec::with_capacity(NUM_BLOCKS);
    for i in 0..NUM_BLOCKS {
        blocks.push(Block {
            dense_op: *rng.choice(&[DenseOp::Fc, DenseOp::Dp]),
            dense_dim: *rng.choice(&DENSE_DIMS[..6]),
            dense_wbits: *rng.choice(&WEIGHT_BITS),
            sparse_op: *rng.choice(&[SparseOp::Efc, SparseOp::Identity]),
            sparse_features: *rng.choice(&SPARSE_FEATURES),
            sparse_wbits: *rng.choice(&WEIGHT_BITS),
            interaction: *rng.choice(&[
                Interaction::None,
                Interaction::Dsi,
                Interaction::Fm,
            ]),
            inter_wbits: *rng.choice(&WEIGHT_BITS),
            dense_in: sample_sources(rng, i),
            sparse_in: sample_sources(rng, i),
        });
    }
    let pim = random_pim(rng);
    Genome {
        name: name.to_string(),
        dataset: dataset.to_string(),
        d_emb: *rng.choice(&SPARSE_DIMS),
        blocks,
        final_wbits: *rng.choice(&WEIGHT_BITS),
        pim,
    }
}

fn sample_sources(rng: &mut Rng, block_idx: usize) -> Vec<usize> {
    let n = rng.range(1, 2.min(block_idx + 1));
    let mut set = std::collections::BTreeSet::new();
    for _ in 0..n {
        set.insert(rng.range(0, block_idx));
    }
    set.into_iter().collect()
}

/// Rejection-sample a feasible PIM config.
pub fn random_pim(rng: &mut Rng) -> PimConfig {
    loop {
        let c = PimConfig {
            xbar: *rng.choice(&XBAR_SIZES),
            dac_bits: *rng.choice(&DAC_OPTIONS),
            cell_bits: *rng.choice(&CELL_OPTIONS),
            adc_bits: *rng.choice(&ADC_OPTIONS),
            ..PimConfig::default()
        };
        if c.feasible() {
            return c;
        }
    }
}

/// All mutation kinds (uniformly sampled by `mutate`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mutation {
    SwapDenseOp,
    SwapSparseOp,
    DenseDim,
    SparseFeatures,
    DenseBits,
    SparseBits,
    InterBits,
    Interaction,
    Connection,
    EmbDim,
    PimXbar,
    PimDac,
    PimCell,
    PimAdc,
}

pub const ALL_MUTATIONS: [Mutation; 14] = [
    Mutation::SwapDenseOp,
    Mutation::SwapSparseOp,
    Mutation::DenseDim,
    Mutation::SparseFeatures,
    Mutation::DenseBits,
    Mutation::SparseBits,
    Mutation::InterBits,
    Mutation::Interaction,
    Mutation::Connection,
    Mutation::EmbDim,
    Mutation::PimXbar,
    Mutation::PimDac,
    Mutation::PimCell,
    Mutation::PimAdc,
];

/// Apply one random mutation within a randomly chosen block (Algorithm 1
/// line 7). Always returns a VALID genome (mutations are constructed to
/// preserve the invariants; PIM mutations re-sample until feasible).
pub fn mutate(g: &Genome, rng: &mut Rng) -> Genome {
    let mut out = g.clone();
    let bi = rng.range(0, out.blocks.len() - 1);
    let kind = *rng.choice(&ALL_MUTATIONS);
    {
        let blk = &mut out.blocks[bi];
        match kind {
            Mutation::SwapDenseOp => {
                blk.dense_op = match blk.dense_op {
                    DenseOp::Fc => DenseOp::Dp,
                    DenseOp::Dp => DenseOp::Fc,
                };
            }
            Mutation::SwapSparseOp => {
                blk.sparse_op = match blk.sparse_op {
                    SparseOp::Efc => SparseOp::Identity,
                    SparseOp::Identity => SparseOp::Efc,
                };
            }
            Mutation::DenseDim => blk.dense_dim = *rng.choice(&DENSE_DIMS[..6]),
            Mutation::SparseFeatures => {
                blk.sparse_features = *rng.choice(&SPARSE_FEATURES)
            }
            Mutation::DenseBits => blk.dense_wbits = *rng.choice(&WEIGHT_BITS),
            Mutation::SparseBits => blk.sparse_wbits = *rng.choice(&WEIGHT_BITS),
            Mutation::InterBits => blk.inter_wbits = *rng.choice(&WEIGHT_BITS),
            Mutation::Interaction => {
                blk.interaction = *rng.choice(&[
                    Interaction::None,
                    Interaction::Dsi,
                    Interaction::Fm,
                ]);
            }
            Mutation::Connection => {
                // re-draw one branch's sources among valid predecessors
                if rng.chance(0.5) {
                    blk.dense_in = sample_sources(rng, bi);
                } else {
                    blk.sparse_in = sample_sources(rng, bi);
                }
            }
            Mutation::EmbDim => out.d_emb = *rng.choice(&SPARSE_DIMS),
            Mutation::PimXbar
            | Mutation::PimDac
            | Mutation::PimCell
            | Mutation::PimAdc => {
                let mut c = out.pim;
                loop {
                    match kind {
                        Mutation::PimXbar => c.xbar = *rng.choice(&XBAR_SIZES),
                        Mutation::PimDac => c.dac_bits = *rng.choice(&DAC_OPTIONS),
                        Mutation::PimCell => {
                            c.cell_bits = *rng.choice(&CELL_OPTIONS)
                        }
                        Mutation::PimAdc => c.adc_bits = *rng.choice(&ADC_OPTIONS),
                        _ => unreachable!(),
                    }
                    if c.feasible() {
                        break;
                    }
                }
                out.pim = c;
            }
        }
    }
    debug_assert!(out.validate().is_ok(), "mutation produced invalid genome");
    out
}

/// |design space| per Table 1 (mirrors arch.py::design_space_size; the
/// paper quotes ≈2×10⁵⁴ with its connection-counting convention, ours
/// enumerates ≈10⁴² — see EXPERIMENTS.md for the accounting difference).
pub fn design_space_size() -> f64 {
    let mut size = 1f64;
    for i in 0..NUM_BLOCKS {
        let conn = ((1u128 << (i + 1)) - 1) as f64;
        let ops = (2 * DENSE_DIMS.len() * 2 * 2 * SPARSE_FEATURES.len() * 2 * 3 * 2)
            as f64;
        size *= conn * conn * ops;
    }
    size *= (SPARSE_DIMS.len() * WEIGHT_BITS.len()) as f64;
    size *= PimConfig::enumerate_feasible().len() as f64;
    size
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::genome::autorac_best;

    #[test]
    fn random_genomes_are_valid() {
        let mut rng = Rng::new(1);
        for i in 0..50 {
            let g = random_genome(&mut rng, "criteo", &format!("r{i}"));
            g.validate().unwrap();
        }
    }

    #[test]
    fn mutations_preserve_validity() {
        let mut rng = Rng::new(2);
        let mut g = autorac_best("criteo");
        for _ in 0..500 {
            g = mutate(&g, &mut rng);
            g.validate().unwrap();
        }
    }

    #[test]
    fn mutations_explore_the_space() {
        let mut rng = Rng::new(3);
        let g = autorac_best("criteo");
        let mut distinct = std::collections::BTreeSet::new();
        for _ in 0..200 {
            distinct.insert(mutate(&g, &mut rng).hash());
        }
        // single-step neighbourhoods overlap (small option sets); a
        // healthy mutation operator still reaches >40 distinct neighbours
        assert!(distinct.len() > 40, "only {} distinct mutants", distinct.len());
    }

    #[test]
    fn pim_mutations_stay_feasible() {
        let mut rng = Rng::new(4);
        let mut g = autorac_best("criteo");
        for _ in 0..200 {
            g = mutate(&g, &mut rng);
            assert!(g.pim.feasible());
        }
    }

    #[test]
    fn space_is_astronomically_large() {
        let s = design_space_size();
        assert!(s > 1e40, "space size {s:e}");
    }
}
