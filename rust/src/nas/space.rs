//! Design-space operations: random sampling and the mutation set of
//! Algorithm 1 ("swapping dense/sparse operators, modifying dense/sparse
//! dimensions, adjusting block-to-block connections, or introducing
//! dense-sparse interaction layers", plus the PIM-side mutations
//! "toggling among different ADC resolutions, DAC options, memristor
//! precisions, and crossbar sizes").

use super::genome::{
    Block, DenseOp, Genome, Interaction, SparseOp, DENSE_DIMS, NUM_BLOCKS,
    SPARSE_DIMS, SPARSE_FEATURES, WEIGHT_BITS,
};
use crate::pim::config::{ADC_OPTIONS, CELL_OPTIONS, DAC_OPTIONS, XBAR_SIZES};
use crate::pim::PimConfig;
use crate::util::rng::Rng;

/// Uniform random genome (mirrors arch.py::random_genome; dense dims are
/// capped at 512 to keep calibration-comparable models).
pub fn random_genome(rng: &mut Rng, dataset: &str, name: &str) -> Genome {
    let mut blocks = Vec::with_capacity(NUM_BLOCKS);
    for i in 0..NUM_BLOCKS {
        blocks.push(Block {
            dense_op: *rng.choice(&[DenseOp::Fc, DenseOp::Dp]),
            dense_dim: *rng.choice(&DENSE_DIMS[..6]),
            dense_wbits: *rng.choice(&WEIGHT_BITS),
            sparse_op: *rng.choice(&[SparseOp::Efc, SparseOp::Identity]),
            sparse_features: *rng.choice(&SPARSE_FEATURES),
            sparse_wbits: *rng.choice(&WEIGHT_BITS),
            interaction: *rng.choice(&[
                Interaction::None,
                Interaction::Dsi,
                Interaction::Fm,
            ]),
            inter_wbits: *rng.choice(&WEIGHT_BITS),
            dense_in: sample_sources(rng, i),
            sparse_in: sample_sources(rng, i),
        });
    }
    let pim = random_pim(rng);
    Genome {
        name: name.to_string(),
        dataset: dataset.to_string(),
        d_emb: *rng.choice(&SPARSE_DIMS),
        blocks,
        final_wbits: *rng.choice(&WEIGHT_BITS),
        pim,
    }
}

fn sample_sources(rng: &mut Rng, block_idx: usize) -> Vec<usize> {
    let n = rng.range(1, 2.min(block_idx + 1));
    let mut set = std::collections::BTreeSet::new();
    for _ in 0..n {
        set.insert(rng.range(0, block_idx));
    }
    set.into_iter().collect()
}

/// Rejection-sample a feasible PIM config.
pub fn random_pim(rng: &mut Rng) -> PimConfig {
    loop {
        let c = PimConfig {
            xbar: *rng.choice(&XBAR_SIZES),
            dac_bits: *rng.choice(&DAC_OPTIONS),
            cell_bits: *rng.choice(&CELL_OPTIONS),
            adc_bits: *rng.choice(&ADC_OPTIONS),
            ..PimConfig::default()
        };
        if c.feasible() {
            return c;
        }
    }
}

/// All mutation kinds (uniformly sampled by `mutate`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mutation {
    SwapDenseOp,
    SwapSparseOp,
    DenseDim,
    SparseFeatures,
    DenseBits,
    SparseBits,
    InterBits,
    Interaction,
    Connection,
    EmbDim,
    PimXbar,
    PimDac,
    PimCell,
    PimAdc,
}

pub const ALL_MUTATIONS: [Mutation; 14] = [
    Mutation::SwapDenseOp,
    Mutation::SwapSparseOp,
    Mutation::DenseDim,
    Mutation::SparseFeatures,
    Mutation::DenseBits,
    Mutation::SparseBits,
    Mutation::InterBits,
    Mutation::Interaction,
    Mutation::Connection,
    Mutation::EmbDim,
    Mutation::PimXbar,
    Mutation::PimDac,
    Mutation::PimCell,
    Mutation::PimAdc,
];

/// Apply one random mutation within a randomly chosen block (Algorithm 1
/// line 7). Always returns a VALID genome (mutations are constructed to
/// preserve the invariants; PIM mutations re-sample until feasible).
pub fn mutate(g: &Genome, rng: &mut Rng) -> Genome {
    let mut out = g.clone();
    let bi = rng.range(0, out.blocks.len() - 1);
    let kind = *rng.choice(&ALL_MUTATIONS);
    {
        let blk = &mut out.blocks[bi];
        match kind {
            Mutation::SwapDenseOp => {
                blk.dense_op = match blk.dense_op {
                    DenseOp::Fc => DenseOp::Dp,
                    DenseOp::Dp => DenseOp::Fc,
                };
            }
            Mutation::SwapSparseOp => {
                blk.sparse_op = match blk.sparse_op {
                    SparseOp::Efc => SparseOp::Identity,
                    SparseOp::Identity => SparseOp::Efc,
                };
            }
            Mutation::DenseDim => blk.dense_dim = *rng.choice(&DENSE_DIMS[..6]),
            Mutation::SparseFeatures => {
                blk.sparse_features = *rng.choice(&SPARSE_FEATURES)
            }
            Mutation::DenseBits => blk.dense_wbits = *rng.choice(&WEIGHT_BITS),
            Mutation::SparseBits => blk.sparse_wbits = *rng.choice(&WEIGHT_BITS),
            Mutation::InterBits => blk.inter_wbits = *rng.choice(&WEIGHT_BITS),
            Mutation::Interaction => {
                blk.interaction = *rng.choice(&[
                    Interaction::None,
                    Interaction::Dsi,
                    Interaction::Fm,
                ]);
            }
            Mutation::Connection => {
                // re-draw one branch's sources among valid predecessors
                if rng.chance(0.5) {
                    blk.dense_in = sample_sources(rng, bi);
                } else {
                    blk.sparse_in = sample_sources(rng, bi);
                }
            }
            Mutation::EmbDim => out.d_emb = *rng.choice(&SPARSE_DIMS),
            Mutation::PimXbar
            | Mutation::PimDac
            | Mutation::PimCell
            | Mutation::PimAdc => {
                let mut c = out.pim;
                loop {
                    match kind {
                        Mutation::PimXbar => c.xbar = *rng.choice(&XBAR_SIZES),
                        Mutation::PimDac => c.dac_bits = *rng.choice(&DAC_OPTIONS),
                        Mutation::PimCell => {
                            c.cell_bits = *rng.choice(&CELL_OPTIONS)
                        }
                        Mutation::PimAdc => c.adc_bits = *rng.choice(&ADC_OPTIONS),
                        _ => unreachable!(),
                    }
                    if c.feasible() {
                        break;
                    }
                }
                out.pim = c;
            }
        }
    }
    debug_assert!(out.validate().is_ok(), "mutation produced invalid genome");
    out
}

/// The Table-1 design space as a checkable object: `contains` answers
/// whether a genome could have been produced by `random_genome` /
/// `mutate` (the searchable subset — e.g. dense dims are capped at 512
/// for calibration comparability, sources are strictly-ordered sets).
/// Used by the qcheck property layer to pin the mutation operators.
#[derive(Clone, Copy, Debug, Default)]
pub struct SearchSpace;

impl SearchSpace {
    pub fn contains(&self, g: &Genome) -> bool {
        if g.validate().is_err() || g.blocks.len() != NUM_BLOCKS {
            return false;
        }
        for b in &g.blocks {
            if !DENSE_DIMS[..6].contains(&b.dense_dim)
                || !SPARSE_FEATURES.contains(&b.sparse_features)
            {
                return false;
            }
            // sample_sources draws ≤ 2 entries from a BTreeSet: strictly
            // increasing, 1–2 long (emptiness/range checked by validate)
            for sources in [&b.dense_in, &b.sparse_in] {
                if !(1..=2).contains(&sources.len())
                    || !sources.windows(2).all(|w| w[0] < w[1])
                {
                    return false;
                }
            }
        }
        // PIM genome drawn from the Table-1 option sets, ADC rule holds
        XBAR_SIZES.contains(&g.pim.xbar)
            && DAC_OPTIONS.contains(&g.pim.dac_bits)
            && CELL_OPTIONS.contains(&g.pim.cell_bits)
            && ADC_OPTIONS.contains(&g.pim.adc_bits)
            && g.pim.feasible()
    }
}

/// |design space| per Table 1 (mirrors arch.py::design_space_size; the
/// paper quotes ≈2×10⁵⁴ with its connection-counting convention, ours
/// enumerates ≈10⁴² — see EXPERIMENTS.md for the accounting difference).
pub fn design_space_size() -> f64 {
    let mut size = 1f64;
    for i in 0..NUM_BLOCKS {
        let conn = ((1u128 << (i + 1)) - 1) as f64;
        let ops = (2 * DENSE_DIMS.len() * 2 * 2 * SPARSE_FEATURES.len() * 2 * 3 * 2)
            as f64;
        size *= conn * conn * ops;
    }
    size *= (SPARSE_DIMS.len() * WEIGHT_BITS.len()) as f64;
    size *= PimConfig::enumerate_feasible().len() as f64;
    size
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::genome::autorac_best;

    #[test]
    fn random_genomes_are_valid() {
        let mut rng = Rng::new(1);
        for i in 0..50 {
            let g = random_genome(&mut rng, "criteo", &format!("r{i}"));
            g.validate().unwrap();
        }
    }

    #[test]
    fn mutations_preserve_validity() {
        let mut rng = Rng::new(2);
        let mut g = autorac_best("criteo");
        for _ in 0..500 {
            g = mutate(&g, &mut rng);
            g.validate().unwrap();
        }
    }

    #[test]
    fn mutations_explore_the_space() {
        let mut rng = Rng::new(3);
        let g = autorac_best("criteo");
        let mut distinct = std::collections::BTreeSet::new();
        for _ in 0..200 {
            distinct.insert(mutate(&g, &mut rng).hash());
        }
        // single-step neighbourhoods overlap (small option sets); a
        // healthy mutation operator still reaches >40 distinct neighbours
        assert!(distinct.len() > 40, "only {} distinct mutants", distinct.len());
    }

    #[test]
    fn pim_mutations_stay_feasible() {
        let mut rng = Rng::new(4);
        let mut g = autorac_best("criteo");
        for _ in 0..200 {
            g = mutate(&g, &mut rng);
            assert!(g.pim.feasible());
        }
    }

    #[test]
    fn space_is_astronomically_large() {
        let s = design_space_size();
        assert!(s > 1e40, "space size {s:e}");
    }

    #[test]
    fn reference_genomes_are_inside_the_space() {
        let space = SearchSpace;
        for ds in ["criteo", "avazu", "kdd"] {
            assert!(space.contains(&autorac_best(ds)), "{ds}");
        }
    }

    #[test]
    fn contains_rejects_out_of_space_genomes() {
        let space = SearchSpace;
        let mut big = autorac_best("criteo");
        big.blocks[0].dense_dim = 1024; // valid genome, outside the search cap
        assert!(big.validate().is_ok());
        assert!(!space.contains(&big));
        let mut dup = autorac_best("criteo");
        dup.blocks[4].dense_in = vec![3, 3]; // not a set
        assert!(!space.contains(&dup));
        let mut wide = autorac_best("criteo");
        wide.blocks[4].dense_in = vec![1, 2, 3]; // arity beyond sample_sources
        assert!(wide.validate().is_ok());
        assert!(!space.contains(&wide));
        let mut bad = autorac_best("criteo");
        bad.d_emb = 100; // invalid outright
        assert!(!space.contains(&bad));
    }

    #[test]
    fn qcheck_mutations_stay_inside_the_space() {
        use crate::util::qcheck::qcheck;
        let space = SearchSpace;
        qcheck(150, |g| {
            let dataset = *g.choose(&["criteo", "avazu", "kdd"]);
            let rng = g.rng();
            let mut genome = random_genome(rng, dataset, "q");
            crate::prop_assert!(space.contains(&genome), "random_genome escaped");
            for step in 0..8 {
                genome = mutate(&genome, rng);
                crate::prop_assert!(
                    space.contains(&genome),
                    "mutation step {step} escaped the space"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn qcheck_mutation_preserves_dataset_and_arity() {
        use crate::util::qcheck::qcheck;
        qcheck(150, |g| {
            let dataset = *g.choose(&["criteo", "avazu", "kdd"]);
            let rng = g.rng();
            let parent = random_genome(rng, dataset, "q");
            let child = mutate(&parent, rng);
            crate::prop_assert_eq!(&child.dataset, &parent.dataset);
            crate::prop_assert_eq!(child.blocks.len(), parent.blocks.len());
            // per-block source arity stays within the sampled bounds
            for (i, b) in child.blocks.iter().enumerate() {
                crate::prop_assert!(
                    (1..=2).contains(&b.dense_in.len())
                        && (1..=2).contains(&b.sparse_in.len()),
                    "block {i} source arity escaped"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn genome_hash_has_no_collisions_across_10k_samples() {
        use crate::mapping::genome_eval_key;
        use std::collections::BTreeMap;
        let mut rng = Rng::new(0x10_000);
        // structural-hash round trip: identical structure → identical
        // key; over 10k random draws no two distinct structures collide
        let mut seen: BTreeMap<u64, String> = BTreeMap::new();
        for i in 0..10_000 {
            // constant name: the canonical form IS the structure
            let g = random_genome(&mut rng, "criteo", "h");
            let key = genome_eval_key(&g);
            assert_eq!(key, genome_eval_key(&g.clone()), "sample {i} unstable");
            let repr = g.to_json().to_string_compact();
            if let Some(prev) = seen.insert(key, repr.clone()) {
                assert_eq!(prev, repr, "64-bit structural hash collision at {i}");
            }
        }
        assert!(seen.len() > 9_000, "draws were not diverse: {}", seen.len());
    }
}
