//! NAS subsystem (S11/S12): genome schema, design-space operations,
//! regularized evolution (Algorithm 1), and the calibrated accuracy
//! surrogate.

pub mod accuracy;
pub mod evolution;
pub mod genome;
pub mod space;

pub use accuracy::{genome_features, Surrogate};
pub use evolution::{Individual, Search, SearchConfig, SearchTrace};
pub use genome::{autorac_best, nasrec_like, Block, BlockShape, DenseOp, Genome, Interaction, SparseOp};
pub use space::{design_space_size, mutate, random_genome};
