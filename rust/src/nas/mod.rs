//! NAS subsystem (S11/S12 + S20–S22): genome schema, design-space
//! operations, regularized evolution (Algorithm 1, serial reference),
//! the parallel/memoized/Pareto-aware engine, and the calibrated
//! accuracy surrogate.

pub mod accuracy;
pub mod cache;
pub mod evolution;
pub mod genome;
pub mod parallel;
pub mod pareto;
pub mod space;

pub use accuracy::{genome_features, Surrogate};
pub use cache::{CacheStats, EvalCache};
pub use evolution::{Individual, Search, SearchConfig, SearchTrace};
pub use genome::{autorac_best, nasrec_like, Block, BlockShape, DenseOp, Genome, Interaction, SparseOp};
pub use parallel::ParallelSearch;
pub use pareto::{dominates, ParetoArchive, ParetoPoint};
pub use space::{design_space_size, mutate, random_genome, SearchSpace};
