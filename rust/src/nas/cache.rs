//! Genome-keyed evaluation cache (S22).
//!
//! The co-search re-visits structurally identical genomes constantly —
//! mutation neighbourhoods are small (14 kinds over small option sets),
//! so a child of a well-sampled parent frequently reproduces a candidate
//! the search has already priced. Both halves of an evaluation are pure
//! functions of the genome structure (the surrogate is deterministic and
//! `sim::simulate` runs on a fixed workload seed), so memoizing by
//! [`crate::mapping::genome_eval_key`] skips the redundant
//! `map_genome` + `simulate` work without changing a single bit of the
//! search trace — pinned by the cache-on/off equivalence check in
//! `tests/search_determinism.rs`.

use std::collections::HashMap;

/// A memoized evaluation outcome: surrogate test loss and the
/// `[1/throughput, area, power]` simulator metrics. The scalar criterion
/// is *not* cached — it depends on the λ weights and targets, which the
/// engine applies on top.
pub type EvalOutcome = (f64, [f64; 3]);

/// Hit/miss accounting for one search run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: usize,
    pub misses: usize,
}

impl CacheStats {
    pub fn lookups(&self) -> usize {
        self.hits + self.misses
    }

    /// Fraction of lookups served from the cache (0.0 when none ran).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }
}

/// Structural-hash-keyed evaluation memo. A disabled cache never hits,
/// never stores, and never counts — so an engine built with `cache:
/// false` runs every simulation and reports zeroed stats.
pub struct EvalCache {
    map: HashMap<u64, EvalOutcome>,
    enabled: bool,
    stats: CacheStats,
}

impl EvalCache {
    pub fn new(enabled: bool) -> EvalCache {
        EvalCache {
            map: HashMap::new(),
            enabled,
            stats: CacheStats::default(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Look up a structural key, counting the hit or miss.
    pub fn get(&mut self, key: u64) -> Option<EvalOutcome> {
        if !self.enabled {
            return None;
        }
        match self.map.get(&key).copied() {
            Some(v) => {
                self.stats.hits += 1;
                Some(v)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Store an evaluation (no-op when disabled). Re-inserting a key is
    /// harmless: evaluation is pure, so the value is identical.
    pub fn insert(&mut self, key: u64, value: EvalOutcome) {
        if self.enabled {
            self.map.insert(key, value);
        }
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Number of distinct genomes memoized.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_hits_and_misses() {
        let mut c = EvalCache::new(true);
        assert_eq!(c.get(1), None);
        c.insert(1, (0.5, [1.0, 2.0, 3.0]));
        assert_eq!(c.get(1), Some((0.5, [1.0, 2.0, 3.0])));
        assert_eq!(c.get(2), None);
        assert_eq!(c.stats(), CacheStats { hits: 1, misses: 2 });
        assert!((c.stats().hit_rate() - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let mut c = EvalCache::new(true);
        let v = (0.1 + 0.2, [f64::MIN_POSITIVE, 1e300, -0.0]);
        c.insert(7, v);
        let got = c.get(7).unwrap();
        assert_eq!(got.0.to_bits(), v.0.to_bits());
        for i in 0..3 {
            assert_eq!(got.1[i].to_bits(), v.1[i].to_bits());
        }
    }

    #[test]
    fn disabled_cache_never_hits_or_counts() {
        let mut c = EvalCache::new(false);
        c.insert(1, (0.5, [0.0; 3]));
        assert_eq!(c.get(1), None);
        assert!(c.is_empty());
        assert_eq!(c.stats().lookups(), 0);
        assert_eq!(c.stats().hit_rate(), 0.0);
    }
}
