//! Operator→PIM mapping engine (S8). `cost` holds the closed-form
//! bit-serial dataflow math; `mapper` builds the execution DAG and the
//! tile inventory for a genome under Smart (paper §3.2) or Naive
//! (Table 3 comparison) mapping; `banks` (S24) materializes a genome as
//! functional `BatchedXbar` weight banks for the native serving backend.

pub mod banks;
pub mod cost;
pub mod mapper;

pub use banks::{
    build_pim_net, build_pim_net_with, BankScratch, NetScratch, PimBank, PimNet,
};
pub use cost::{cycle_time_ns, matmul_cost, OpCost};
pub use mapper::{genome_eval_key, map_genome, MapStyle, MappedModel, MappedOp, OpKind};
