//! Functional PIM bank construction (S24): materialize a genome as a
//! stack of [`BatchedXbar`]-programmed weight banks the native
//! [`crate::coordinator::PimEngine`] serving backend executes — real
//! crossbar math on the request path, fully offline (weights are
//! deterministic random quantized tensors; no artifacts).
//!
//! The mapped network is the serving-shaped projection of the genome:
//! a quantized bottom MLP over the dense features (one bank per block,
//! each at that block's `dense_wbits` — the searched mixed precision),
//! an `inter_wbits` projection into embedding space, an FM-style
//! second-order interaction with the gathered embeddings (the
//! digital-equivalent of the transposed-array + MBSA reduction, whose
//! analog/pairwise equivalence `pim/transposed.rs` pins), and a
//! `final_wbits` scoring head. Every linear layer runs through
//! [`BatchedXbar::mvm_corrected_batch`], so serving cost and fidelity
//! follow the PIM genome, and per-row activation quantization keeps
//! scores **batch-size invariant** (bit-identical however requests are
//! batched — pinned in tests).

use crate::nas::genome::{Genome, Interaction};
use crate::pim::fault::FaultCounts;
use crate::pim::kernel::{BatchedXbar, XbarOptions, XbarScratch};
use crate::pim::{quant_act_into, quant_sym, MatI32, PimConfig};
use crate::util::rng::{seed_from_name, Rng};

/// One crossbar-programmed linear layer: fp32 in/out, bit-serial integer
/// inside (quantize → batched MVM → offset-correct → rescale).
pub struct PimBank {
    pub name: String,
    pub xbar: BatchedXbar,
    pub w_scale: f32,
    /// logical input dim (≤ `xbar.k`, which is padded to a tile multiple)
    pub k_in: usize,
    pub n_out: usize,
}

/// Reusable buffers for [`PimBank::forward_batch`] (shared by every bank
/// of a net; allocation-free after warmup). `xbar.activity` accumulates
/// the crossbar event counts of every pass run through this scratch.
#[derive(Default)]
pub struct BankScratch {
    pub xbar: XbarScratch,
    /// detection/repair outcomes accumulated by every pass through this
    /// scratch (S34); drained up the stack by the serving engine
    pub fault: FaultCounts,
    x_u: Vec<i32>,
    row_q: Vec<i32>,
    scales: Vec<f32>,
    acc: Vec<i64>,
}

impl BankScratch {
    /// Scratch whose crossbar passes may use up to `threads` worker
    /// threads ([`XbarScratch::with_threads`]) — bit-identical results
    /// at any setting.
    pub fn with_threads(threads: usize) -> BankScratch {
        BankScratch {
            xbar: XbarScratch::with_threads(threads),
            ..BankScratch::default()
        }
    }
}

impl PimBank {
    /// Program an already-quantized weight matrix (`wq` within
    /// `cfg.w_bits`) with its dequantization scale.
    pub fn from_quantized(
        name: &str,
        wq: &MatI32,
        w_scale: f32,
        cfg: PimConfig,
    ) -> PimBank {
        PimBank::from_quantized_with(name, wq, w_scale, cfg, &XbarOptions::default())
    }

    /// [`PimBank::from_quantized`] with fault-tolerance options (S34).
    /// `opts.label` is overridden with the bank name, so two banks of
    /// one net draw independent fault substreams from the same spec.
    pub fn from_quantized_with(
        name: &str,
        wq: &MatI32,
        w_scale: f32,
        cfg: PimConfig,
        opts: &XbarOptions,
    ) -> PimBank {
        let opts = XbarOptions {
            label: name.to_string(),
            ..opts.clone()
        };
        PimBank {
            name: name.to_string(),
            xbar: BatchedXbar::program_with(wq, cfg, &opts),
            w_scale,
            k_in: wq.rows,
            n_out: wq.cols,
        }
    }

    /// Deterministic He-style random weights quantized to `w_bits` and
    /// programmed as one differential bit-plane bank. The substream is
    /// derived from `(seed, name)`, so a bank's weights depend only on
    /// its place in the net — never on construction order.
    pub fn random(
        name: &str,
        k_in: usize,
        n_out: usize,
        w_bits: usize,
        base: PimConfig,
        seed: u64,
    ) -> PimBank {
        PimBank::random_with(
            name,
            k_in,
            n_out,
            w_bits,
            base,
            seed,
            &XbarOptions::default(),
        )
    }

    /// [`PimBank::random`] with fault-tolerance options (S34): same
    /// weights as `random` for the same `(seed, name)` — injection and
    /// spares never change what the bank was *programmed* with, only
    /// what the device *holds*.
    #[allow(clippy::too_many_arguments)]
    pub fn random_with(
        name: &str,
        k_in: usize,
        n_out: usize,
        w_bits: usize,
        base: PimConfig,
        seed: u64,
        opts: &XbarOptions,
    ) -> PimBank {
        let mut rng = Rng::new(seed_from_name(seed, &format!("pimbank/{name}")));
        let sd = (2.0 / k_in.max(1) as f64).sqrt();
        let wf: Vec<f32> = (0..k_in * n_out)
            .map(|_| (rng.normal() * sd) as f32)
            .collect();
        let (q, w_scale) = quant_sym(&wf, w_bits);
        let wq = MatI32 {
            rows: k_in,
            cols: n_out,
            data: q,
        };
        PimBank::from_quantized_with(name, &wq, w_scale, base.with_wbits(w_bits), opts)
    }

    /// Batched linear: `x` is `[b × k_in]` fp32; appends `[b × n_out]`
    /// to `out`. Rows are quantized independently (per-row scale), so
    /// each output row is bit-identical to the per-vector
    /// [`crate::pim::crossbar::pim_linear_vec`] reference on the same
    /// programmed weights.
    ///
    /// `&mut self` because detection triggers repair: when the ABFT
    /// check flags tiles, they are reprogrammed onto spare slots and
    /// the batch re-runs — served scores off a repaired bank are
    /// bit-identical to fault-free hardware. When no (working) spare is
    /// left the bank serves flagged-approximate and books the batch's
    /// rows in `scratch.fault.corrupt_rows` instead of returning silent
    /// garbage (DESIGN.md §7.13).
    pub fn forward_batch(
        &mut self,
        x: &[f32],
        b: usize,
        out: &mut Vec<f32>,
        scratch: &mut BankScratch,
    ) {
        debug_assert_eq!(x.len(), b * self.k_in);
        let k = self.xbar.k;
        let x_bits = self.xbar.cfg.x_bits;
        let offset = 1i32 << (x_bits - 1); // pad value (= 0.0 pre-offset)
        scratch.x_u.clear();
        scratch.x_u.reserve(b * k);
        scratch.scales.clear();
        for j in 0..b {
            let row = &x[j * self.k_in..(j + 1) * self.k_in];
            let scale = quant_act_into(row, x_bits, &mut scratch.row_q);
            scratch.scales.push(scale);
            scratch.x_u.extend_from_slice(&scratch.row_q);
            scratch.x_u.resize((j + 1) * k, offset);
        }
        scratch.acc.clear();
        scratch.acc.resize(b * self.n_out, 0);
        let faulty0 = scratch.xbar.activity.faulty_tiles;
        self.xbar
            .mvm_corrected_batch(&scratch.x_u, b, &mut scratch.acc, &mut scratch.xbar);
        // S34 repair loop: every flagged tile is remapped onto a spare
        // and the whole batch re-runs on the repaired bank. Bounded:
        // each iteration either consumes at least one spare or exits in
        // degraded mode, so the loop ends within the spare budget.
        while !scratch.xbar.flagged.is_empty() {
            let mut repaired = 0u64;
            for i in 0..scratch.xbar.flagged.len() {
                let t = scratch.xbar.flagged[i] as usize;
                if self.xbar.repair_tile(t) {
                    repaired += 1;
                }
            }
            scratch.fault.tiles_repaired += repaired;
            if repaired == 0 {
                // unrepairable: what stands in `acc` ships, flagged
                scratch.fault.corrupt_rows += b as u64;
                break;
            }
            self.xbar.mvm_corrected_batch(
                &scratch.x_u,
                b,
                &mut scratch.acc,
                &mut scratch.xbar,
            );
        }
        // detection events, re-runs included (a tile that flags again
        // after a partial repair pass is a fresh detection)
        scratch.fault.tiles_faulty +=
            scratch.xbar.activity.faulty_tiles - faulty0;
        out.reserve(b * self.n_out);
        for j in 0..b {
            let x_scale = scratch.scales[j];
            out.extend(
                scratch.acc[j * self.n_out..(j + 1) * self.n_out]
                    .iter()
                    // same association as pim_linear_vec: (v·xs)·ws
                    .map(|&v| v as f32 * x_scale * self.w_scale),
            );
        }
    }
}

/// A genome materialized for serving: the bank stack plus the feature
/// geometry it was built for.
pub struct PimNet {
    /// one bank per genome block (that block's `dense_wbits`)
    pub bottom: Vec<PimBank>,
    /// last bottom dim → `d_emb`, at the first interacting block's
    /// `inter_wbits` (the searched interaction precision)
    pub proj: PimBank,
    /// `[bottom_out ‖ fm] → 1` scoring head at `final_wbits`
    pub head: PimBank,
    pub n_dense: usize,
    pub n_sparse: usize,
    pub d_emb: usize,
}

/// Reusable buffers for [`PimNet::forward_batch`].
#[derive(Default)]
pub struct NetScratch {
    pub bank: BankScratch,
    a: Vec<f32>,
    bx: Vec<f32>,
    fmv: Vec<f32>,
    hin: Vec<f32>,
    logits: Vec<f32>,
}

impl NetScratch {
    /// Scratch whose crossbar passes may use up to `threads` worker
    /// threads — a pure wall-clock knob (scores are bit-identical at
    /// any setting, test-pinned).
    pub fn with_threads(threads: usize) -> NetScratch {
        NetScratch {
            bank: BankScratch::with_threads(threads),
            ..NetScratch::default()
        }
    }
}

/// Build the serving bank stack of a genome for a dataset geometry
/// (`n_dense` dense features, `n_sparse` embedding tables of `d_emb`
/// dims — the *store's* dims, which may differ from `g.d_emb`).
pub fn build_pim_net(
    g: &Genome,
    n_dense: usize,
    n_sparse: usize,
    d_emb: usize,
    seed: u64,
) -> crate::Result<PimNet> {
    build_pim_net_with(g, n_dense, n_sparse, d_emb, seed, &XbarOptions::default())
}

/// [`build_pim_net`] with fault-tolerance options applied uniformly to
/// every bank (S34). Each bank overrides `opts.label` with its own
/// name, so fault substreams stay per-bank-independent, and the
/// programmed weights are identical to a fault-free build of the same
/// seed (injection corrupts the *device*, never the weight draw).
pub fn build_pim_net_with(
    g: &Genome,
    n_dense: usize,
    n_sparse: usize,
    d_emb: usize,
    seed: u64,
    opts: &XbarOptions,
) -> crate::Result<PimNet> {
    g.validate()?;
    crate::ensure!(
        n_dense > 0 && d_emb > 0,
        "PimNet needs dense features and embedding dims (got {n_dense}/{d_emb})"
    );
    let mut bottom = Vec::with_capacity(g.blocks.len());
    let mut din = n_dense;
    for (i, blk) in g.blocks.iter().enumerate() {
        bottom.push(PimBank::random_with(
            &format!("bottom{i}"),
            din,
            blk.dense_dim,
            blk.dense_wbits,
            g.pim,
            seed,
            opts,
        ));
        din = blk.dense_dim;
    }
    let inter_bits = g
        .blocks
        .iter()
        .find(|b| b.interaction != Interaction::None)
        .map(|b| b.inter_wbits)
        .unwrap_or(g.final_wbits);
    let proj = PimBank::random_with("proj", din, d_emb, inter_bits, g.pim, seed, opts);
    let head =
        PimBank::random_with("head", din + d_emb, 1, g.final_wbits, g.pim, seed, opts);
    Ok(PimNet {
        bottom,
        proj,
        head,
        n_dense,
        n_sparse,
        d_emb,
    })
}

impl PimNet {
    /// Score a batch: `dense` `[b × n_dense]`, `sparse` `[b × n_sparse ×
    /// d_emb]` (the gathered embeddings) → `[b]` click probabilities.
    /// Rows are independent end to end, so results do not depend on how
    /// requests were batched.
    pub fn forward_batch(
        &mut self,
        dense: &[f32],
        sparse: &[f32],
        b: usize,
        scratch: &mut NetScratch,
    ) -> Vec<f32> {
        let mut out = Vec::with_capacity(b);
        self.forward_batch_into(dense, sparse, b, &mut out, scratch);
        out
    }

    /// [`PimNet::forward_batch`] into a caller-owned buffer (cleared
    /// first) — the allocation-free variant the serving worker runs:
    /// with a warmed `out` and `scratch`, a pass allocates nothing.
    /// (`&mut self`: ABFT detection may remap flagged tiles onto
    /// spares mid-pass — see [`PimBank::forward_batch`].)
    pub fn forward_batch_into(
        &mut self,
        dense: &[f32],
        sparse: &[f32],
        b: usize,
        out: &mut Vec<f32>,
        scratch: &mut NetScratch,
    ) {
        let d = self.d_emb;
        let ns = self.n_sparse;
        // bottom MLP (ReLU after every bank)
        scratch.a.clear();
        scratch.a.extend_from_slice(&dense[..b * self.n_dense]);
        for bank in &mut self.bottom {
            scratch.bx.clear();
            bank.forward_batch(&scratch.a, b, &mut scratch.bx, &mut scratch.bank);
            for v in scratch.bx.iter_mut() {
                *v = v.max(0.0);
            }
            std::mem::swap(&mut scratch.a, &mut scratch.bx);
        }
        // project into embedding space at the interaction precision
        scratch.bx.clear();
        self.proj
            .forward_batch(&scratch.a, b, &mut scratch.bx, &mut scratch.bank);
        // FM second-order pooling over (embeddings + projected bottom):
        // 0.5·((Σ_v x_v)² − Σ_v x_v²) per dim — the Σx ∥ Σx² + MBSA
        // reduction of pim/transposed.rs, computed digitally here.
        scratch.fmv.clear();
        scratch.fmv.reserve(b * d);
        for j in 0..b {
            for t in 0..d {
                let pv = scratch.bx[j * d + t] as f64;
                let (mut s, mut ss) = (pv, pv * pv);
                for f in 0..ns {
                    let v = sparse[(j * ns + f) * d + t] as f64;
                    s += v;
                    ss += v * v;
                }
                scratch.fmv.push((0.5 * (s * s - ss)) as f32);
            }
        }
        // head over [bottom_out ‖ fm]
        let dl = self.bottom.last().map_or(self.n_dense, |bk| bk.n_out);
        scratch.hin.clear();
        scratch.hin.reserve(b * (dl + d));
        for j in 0..b {
            scratch.hin.extend_from_slice(&scratch.a[j * dl..(j + 1) * dl]);
            scratch.hin.extend_from_slice(&scratch.fmv[j * d..(j + 1) * d]);
        }
        scratch.logits.clear();
        self.head
            .forward_batch(&scratch.hin, b, &mut scratch.logits, &mut scratch.bank);
        out.clear();
        out.extend(scratch.logits.iter().map(|&l| 1.0 / (1.0 + (-l).exp())));
    }

    fn banks(&self) -> impl Iterator<Item = &PimBank> {
        self.bottom
            .iter()
            .chain(std::iter::once(&self.proj))
            .chain(std::iter::once(&self.head))
    }

    /// Advance every bank's drift fuse by one served batch; returns
    /// `true` if any bank's drift wave landed (the device twin of the
    /// coordinator-level `CrashAfter`/`SlowAfter` arming).
    pub fn tick_drift(&mut self) -> bool {
        let mut any = false;
        for bank in self
            .bottom
            .iter_mut()
            .chain(std::iter::once(&mut self.proj))
            .chain(std::iter::once(&mut self.head))
        {
            any |= bank.xbar.tick_drift();
        }
        any
    }

    /// Spare tile slots still unallocated across every bank.
    pub fn spares_free(&self) -> usize {
        self.banks().map(|b| b.xbar.spares_free()).sum()
    }

    /// Logical tiles currently mapped to (possibly) corrupted content,
    /// net-wide — ground truth for tests and benches.
    pub fn corrupt_tiles(&self) -> usize {
        self.banks()
            .map(|b| b.xbar.corrupt_logical_tiles().len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::genome::autorac_best;
    use crate::pim::crossbar::pim_linear_vec;
    use crate::pim::{ProgrammedXbar, XbarActivity};

    #[test]
    fn bank_forward_matches_per_vector_reference() {
        let cfg = PimConfig::default();
        let mut rng = Rng::new(7);
        let (k, n) = (50, 12);
        let wf: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let (q, w_scale) = quant_sym(&wf, cfg.w_bits);
        let wq = MatI32 {
            rows: k,
            cols: n,
            data: q,
        };
        let mut bank = PimBank::from_quantized("t", &wq, w_scale, cfg);
        let refx = ProgrammedXbar::program(&wq, cfg);
        let b = 5;
        let x: Vec<f32> = (0..b * k).map(|_| rng.normal() as f32).collect();
        let mut got = Vec::new();
        let mut scratch = BankScratch::default();
        bank.forward_batch(&x, b, &mut got, &mut scratch);
        for j in 0..b {
            let mut act = XbarActivity::default();
            let want = pim_linear_vec(&x[j * k..(j + 1) * k], w_scale, &refx, &mut act);
            assert_eq!(&got[j * n..(j + 1) * n], &want[..], "row {j}");
        }
        assert!(scratch.xbar.activity.read_cycles > 0);
    }

    #[test]
    fn net_probs_are_valid_and_deterministic() {
        let g = autorac_best("criteo");
        let mut net = build_pim_net(&g, 13, 26, 16, 42).unwrap();
        let b = 4;
        let mut rng = Rng::new(9);
        let dense: Vec<f32> = (0..b * 13).map(|_| rng.normal() as f32).collect();
        let sparse: Vec<f32> =
            (0..b * 26 * 16).map(|_| (rng.normal() * 0.05) as f32).collect();
        let mut s1 = NetScratch::default();
        let p1 = net.forward_batch(&dense, &sparse, b, &mut s1);
        let mut s2 = NetScratch::default();
        let p2 = net.forward_batch(&dense, &sparse, b, &mut s2);
        assert_eq!(p1.len(), b);
        assert!(p1.iter().all(|p| (0.0..=1.0).contains(p)));
        assert!(p1.iter().zip(&p2).all(|(a, c)| a.to_bits() == c.to_bits()));
    }

    #[test]
    fn net_scores_are_batch_size_invariant() {
        // per-row quantization ⇒ batching is purely a throughput choice
        let g = autorac_best("avazu");
        let (nd, ns, d) = (10, 9, 8);
        let mut net = build_pim_net(&g, nd, ns, d, 3).unwrap();
        let b = 6;
        let mut rng = Rng::new(11);
        let dense: Vec<f32> = (0..b * nd).map(|_| rng.normal() as f32).collect();
        let sparse: Vec<f32> =
            (0..b * ns * d).map(|_| (rng.normal() * 0.05) as f32).collect();
        let mut sc = NetScratch::default();
        let batched = net.forward_batch(&dense, &sparse, b, &mut sc);
        for j in 0..b {
            let one = net.forward_batch(
                &dense[j * nd..(j + 1) * nd],
                &sparse[j * ns * d..(j + 1) * ns * d],
                1,
                &mut sc,
            );
            assert_eq!(one[0].to_bits(), batched[j].to_bits(), "row {j}");
        }
    }

    // NB: PimNet/PimEngine thread-invariance (scores bit-identical at
    // any NetScratch::with_threads setting) is pinned once, in
    // tests/xbar_threads.rs — not duplicated here.

    #[test]
    fn banks_follow_genome_mixed_precision() {
        let g = autorac_best("criteo");
        let net = build_pim_net(&g, 13, 26, 16, 1).unwrap();
        assert_eq!(net.bottom.len(), g.blocks.len());
        for (bank, blk) in net.bottom.iter().zip(&g.blocks) {
            assert_eq!(bank.xbar.cfg.w_bits, blk.dense_wbits, "{}", bank.name);
            assert_eq!(bank.n_out, blk.dense_dim);
        }
        assert_eq!(net.head.xbar.cfg.w_bits, g.final_wbits);
        assert_eq!(net.head.n_out, 1);
        assert_eq!(net.proj.n_out, 16);
    }

    #[test]
    fn build_rejects_degenerate_geometry() {
        let g = autorac_best("criteo");
        assert!(build_pim_net(&g, 0, 26, 16, 1).is_err());
        assert!(build_pim_net(&g, 13, 26, 0, 1).is_err());
    }

    #[test]
    fn fault_free_options_net_scores_bit_identical_to_plain_build() {
        use crate::pim::fault::FaultSpec;
        let g = autorac_best("criteo");
        let (nd, ns, d) = (13, 26, 16);
        let mut plain = build_pim_net(&g, nd, ns, d, 42).unwrap();
        // spares reserved + a rate-0 spec: same weights, same device
        let opts = XbarOptions {
            spare_tiles: 2,
            fault: Some(FaultSpec::cells(0.0, 7)),
            ..XbarOptions::default()
        };
        let mut ft = build_pim_net_with(&g, nd, ns, d, 42, &opts).unwrap();
        let b = 3;
        let mut rng = Rng::new(13);
        let dense: Vec<f32> = (0..b * nd).map(|_| rng.normal() as f32).collect();
        let sparse: Vec<f32> =
            (0..b * ns * d).map(|_| (rng.normal() * 0.05) as f32).collect();
        let mut s1 = NetScratch::default();
        let p1 = plain.forward_batch(&dense, &sparse, b, &mut s1);
        let mut s2 = NetScratch::default();
        let p2 = ft.forward_batch(&dense, &sparse, b, &mut s2);
        assert!(p1.iter().zip(&p2).all(|(a, c)| a.to_bits() == c.to_bits()));
        assert!(!s2.bank.fault.any(), "clean device books nothing");
        assert_eq!(ft.corrupt_tiles(), 0);
    }

    #[test]
    fn injected_faults_are_repaired_to_bit_identical_scores() {
        let g = autorac_best("criteo");
        let (nd, ns, d) = (13, 26, 16);
        let mut clean = build_pim_net(&g, nd, ns, d, 42).unwrap();
        let opts = XbarOptions {
            spare_tiles: 2,
            ..XbarOptions::default()
        };
        let mut ft = build_pim_net_with(&g, nd, ns, d, 42, &opts).unwrap();
        // one guaranteed single-cell fault per targeted tile: the head's
        // input row 9 is offset-binary (zero activation still reads
        // 0x80), so the head fault is ALWAYS excited and must flag
        ft.bottom[0].xbar.corrupt_bit(0, 0, 0, 0, 5);
        ft.head.xbar.corrupt_bit(0, 0, 0, 0, 9);
        assert_eq!(ft.corrupt_tiles(), 2);
        let b = 5;
        let mut rng = Rng::new(14);
        let dense: Vec<f32> = (0..b * nd).map(|_| rng.normal() as f32).collect();
        let sparse: Vec<f32> =
            (0..b * ns * d).map(|_| (rng.normal() * 0.05) as f32).collect();
        let mut s1 = NetScratch::default();
        let want = clean.forward_batch(&dense, &sparse, b, &mut s1);
        let mut s2 = NetScratch::default();
        let got = ft.forward_batch(&dense, &sparse, b, &mut s2);
        // the repair loop ran inside the pass: flagged tiles remapped,
        // batch re-run. Single fault per tile ⇒ flag ⟺ output wrong
        // (§7.13 iff theorem), so repaired scores are bit-identical.
        assert!(s2.bank.fault.tiles_faulty > 0, "head fault always excites");
        assert!(s2.bank.fault.tiles_repaired >= 1);
        assert_eq!(s2.bank.fault.corrupt_rows, 0, "good spares: no degrade");
        assert!(want.iter().zip(&got).all(|(a, c)| a.to_bits() == c.to_bits()));
        // a second pass on the repaired net stays clean and silent
        let f0 = s2.bank.fault;
        let again = ft.forward_batch(&dense, &sparse, b, &mut s2);
        assert!(want.iter().zip(&again).all(|(a, c)| a.to_bits() == c.to_bits()));
        assert_eq!(s2.bank.fault, f0, "no new detections after repair");
    }

    #[test]
    fn unrepairable_bank_degrades_and_books_corrupt_rows() {
        let g = autorac_best("criteo");
        let (nd, ns, d) = (13, 26, 16);
        // zero spares: detection must flag, repair must fail, and the
        // pass must book degraded rows instead of silent garbage
        let mut ft = build_pim_net(&g, nd, ns, d, 42).unwrap();
        ft.head.xbar.corrupt_bit(0, 0, 0, 0, 9);
        let b = 5;
        let mut rng = Rng::new(14);
        let dense: Vec<f32> = (0..b * nd).map(|_| rng.normal() as f32).collect();
        let sparse: Vec<f32> =
            (0..b * ns * d).map(|_| (rng.normal() * 0.05) as f32).collect();
        let mut s2 = NetScratch::default();
        ft.forward_batch(&dense, &sparse, b, &mut s2);
        assert!(s2.bank.fault.tiles_faulty > 0, "head fault always excites");
        assert_eq!(s2.bank.fault.tiles_repaired, 0, "no spares to repair onto");
        assert_eq!(s2.bank.fault.corrupt_rows, b as u64, "degrade books the batch");
        assert_eq!(ft.corrupt_tiles(), 1, "the corruption is still there");
    }
}
