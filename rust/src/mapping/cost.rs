//! Closed-form cost primitives for crossbar-mapped operations.
//!
//! The behavioral simulator works at operator granularity; this module
//! provides the per-operator latency/energy/area math from first
//! principles of the bit-serial dataflow:
//!
//! * one **read cycle** = one DAC step applied to one row tile; all
//!   bit-plane/differential arrays fire in parallel (they are separate
//!   physical arrays holding copies of the tiling);
//! * each cycle produces `cols` analog sums per array, digitized by
//!   `xbar/cols_per_adc` time-multiplexed ADCs → the cycle time is
//!   max(analog settle, ADC drain), and cycles pipeline;
//! * weights are **static** for FC/EFC/DSI (programming is setup cost);
//!   the DP/FM engines program *activations* at inference time, which is
//!   exactly why the paper's transposed/pipelined mappings matter.

use crate::pim::{PimConfig, TechParams};

/// Cost of one mapped primitive (per single inference, batch = 1).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OpCost {
    /// critical-path latency (ns)
    pub latency_ns: f64,
    /// total energy (pJ)
    pub energy_pj: f64,
    /// pipeline bottleneck stage (ns) — batch B costs
    /// `latency_ns + (B-1)·bottleneck_ns`
    pub bottleneck_ns: f64,
    /// physical crossbar arrays consumed
    pub arrays: usize,
    /// one-time setup (weight programming) latency / energy
    pub setup_ns: f64,
    pub setup_pj: f64,
}

impl OpCost {
    /// Pipelined batch latency: `latency_ns + (B-1)·bottleneck_ns` —
    /// the fill latency plus one initiation interval per extra request.
    /// Monotone in `batch` (property-tested below).
    pub fn batch_ns(&self, batch: usize) -> f64 {
        self.latency_ns + batch.saturating_sub(1) as f64 * self.bottleneck_ns
    }

    pub fn seq(self, other: OpCost) -> OpCost {
        OpCost {
            latency_ns: self.latency_ns + other.latency_ns,
            energy_pj: self.energy_pj + other.energy_pj,
            bottleneck_ns: self.bottleneck_ns.max(other.bottleneck_ns),
            arrays: self.arrays + other.arrays,
            setup_ns: self.setup_ns.max(other.setup_ns),
            setup_pj: self.setup_pj + other.setup_pj,
        }
    }
}

/// One bit-serial pipeline cycle over an `R×cols` tile: analog read +
/// ADC drain (time multiplexed), pipelined back-to-back.
pub fn cycle_time_ns(cfg: &PimConfig, tech: &TechParams, cols: usize) -> f64 {
    let read = tech.xbar_read_cycle(cfg.xbar, cols, cfg.dac_bits);
    let adc = tech.adc(cfg.adc_bits);
    let n_adc = cfg.xbar.div_ceil(tech.cols_per_adc);
    let conversions_per_adc = cols.div_ceil(n_adc);
    read.latency_ns.max(conversions_per_adc as f64 * adc.latency_ns)
}

/// Matrix multiply `n_vecs` input vectors of length K against a static
/// [K, N] weight matrix programmed across crossbars.
///
/// FC: n_vecs = 1. EFC [nin→nout] over d embedding dims: n_vecs = d.
pub fn matmul_cost(
    k: usize,
    n: usize,
    n_vecs: usize,
    wbits: usize,
    cfg0: &PimConfig,
    tech: &TechParams,
) -> OpCost {
    let cfg = cfg0.with_wbits(wbits);
    let r = cfg.xbar;
    let row_tiles = k.div_ceil(r).max(1);
    let col_tiles = n.div_ceil(r).max(1);
    let planes = cfg.n_planes();
    let chunks = cfg.n_chunks();
    // differential pair × bit planes × spatial tiling
    let arrays = row_tiles * col_tiles * planes * 2;
    let cols_last = n - (col_tiles - 1) * r; // active cols of last tile
    let cycle = cycle_time_ns(&cfg, tech, r.min(n));
    // All row/col tiles and planes run in parallel; the vector stream
    // pipelines: fill = chunks cycles, then one vector per `chunks` cycles
    // (inputs are bit-serial — a new vector can only enter when its
    // predecessor's last chunk has left the wordlines).
    let per_vec = chunks as f64 * cycle;
    let latency = per_vec * n_vecs as f64 + tech.shift_add_ns;
    // Energy: every array fires every cycle of every vector.
    let read_e = tech.xbar_read_cycle(r, r.min(n), cfg.dac_bits).energy_pj;
    let adc = tech.adc(cfg.adc_bits);
    let full_tiles_convs = (col_tiles - 1) * r + cols_last; // = n
    let conversions =
        (n_vecs * chunks * planes * 2 * row_tiles) as f64 * full_tiles_convs as f64
            / col_tiles as f64
            * col_tiles as f64; // per row-tile each col converted
    let energy = (arrays * chunks * n_vecs) as f64 * read_e
        + conversions * adc.energy_pj
        + conversions * tech.shift_add_pj
        + tech.buf_pj_per_byte * ((k + n) * n_vecs) as f64; // IO registers
    // Setup: program all arrays once (arrays in parallel, rows serial).
    let w = tech.xbar_write(r, r.min(n));
    OpCost {
        latency_ns: latency,
        energy_pj: energy,
        bottleneck_ns: per_vec * n_vecs as f64,
        arrays,
        setup_ns: w.latency_ns,
        setup_pj: w.energy_pj * arrays as f64,
    }
}

/// Activation-operand programming: write `n_vecs` vectors of dim `d`
/// into a crossbar at inference time.
///
/// * `transposed = true` (the paper's scheme): one column-parallel pulse
///   per vector, and the writes overlap the producer (`producer_ns`).
/// * `transposed = false` (naive): wait for the producer, buffer +
///   transpose digitally, then program row-serially.
pub fn operand_write_cost(
    d: usize,
    n_vecs: usize,
    producer_ns: f64,
    transposed: bool,
    tech: &TechParams,
) -> OpCost {
    if transposed {
        let w = tech.xbar_write_transposed(d, 1);
        let write_total = w.latency_ns * n_vecs as f64;
        OpCost {
            // overlapped: whichever of producer / write stream dominates,
            // plus one pipeline fill pulse
            latency_ns: producer_ns.max(write_total) + w.latency_ns,
            energy_pj: w.energy_pj * (d * n_vecs) as f64 / d.max(1) as f64
                * d as f64,
            bottleneck_ns: write_total.max(producer_ns),
            arrays: 0,
            setup_ns: 0.0,
            setup_pj: 0.0,
        }
    } else {
        // Naive: the wordline-read dataflow needs the operand stored
        // column-per-vector (Xᵀ), but a conventional array programs row
        // by row — the d×n_vecs matrix costs `d` row pulses, after the
        // whole operand has been buffered and digitally transposed
        // (2 passes). Nothing overlaps the producer.
        let w = tech.xbar_write(d, n_vecs);
        let buf = crate::pim::Buffer::new((d * n_vecs * 2).max(1024));
        let (t_ns, t_pj) = buf.transfer(2 * d * n_vecs);
        OpCost {
            latency_ns: producer_ns + t_ns + w.latency_ns,
            energy_pj: t_pj + w.energy_pj,
            bottleneck_ns: producer_ns + w.latency_ns,
            arrays: 0,
            setup_ns: 0.0,
            setup_pj: 0.0,
        }
    }
}

/// Read phase of an operand-programmed engine: `n_reads` stored vectors
/// interrogated bit-serially (dim `d` wordlines, `cols` columns read).
pub fn operand_read_cost(
    d: usize,
    cols: usize,
    n_reads: usize,
    cfg: &PimConfig,
    tech: &TechParams,
) -> OpCost {
    let chunks = cfg.n_chunks();
    let cycle = cycle_time_ns(cfg, tech, cols.min(cfg.xbar));
    let adc = tech.adc(cfg.adc_bits);
    let read_e = tech
        .xbar_read_cycle(d.min(cfg.xbar), cols.min(cfg.xbar), cfg.dac_bits)
        .energy_pj;
    let cycles = (n_reads * chunks) as f64;
    OpCost {
        latency_ns: cycles * cycle,
        energy_pj: cycles * read_e + cycles * cols as f64 * adc.energy_pj,
        bottleneck_ns: cycles * cycle,
        arrays: (d.div_ceil(cfg.xbar) * cols.div_ceil(cfg.xbar)).max(1),
        setup_ns: 0.0,
        setup_pj: 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PimConfig {
        PimConfig::default()
    }

    #[test]
    fn matmul_scales_with_input_vectors() {
        let t = TechParams::default();
        let a = matmul_cost(128, 64, 1, 8, &cfg(), &t);
        let b = matmul_cost(128, 64, 32, 8, &cfg(), &t);
        assert!(b.latency_ns > 20.0 * a.latency_ns);
        assert!(b.energy_pj > 20.0 * a.energy_pj);
        assert_eq!(a.arrays, b.arrays); // same silicon
    }

    #[test]
    fn four_bit_weights_halve_arrays() {
        let t = TechParams::default();
        let w8 = matmul_cost(128, 128, 1, 8, &cfg(), &t);
        let w4 = matmul_cost(128, 128, 1, 4, &cfg(), &t);
        assert_eq!(w8.arrays, 2 * w4.arrays); // 4 planes vs 2
        assert!(w4.energy_pj < w8.energy_pj);
    }

    #[test]
    fn bigger_crossbars_reduce_latency_via_fewer_tiles() {
        let t = TechParams::default();
        let small = matmul_cost(
            256,
            256,
            1,
            8,
            &PimConfig { xbar: 16, cell_bits: 1, ..cfg() },
            &t,
        );
        let big = matmul_cost(
            256,
            256,
            1,
            8,
            &PimConfig { xbar: 64, cell_bits: 1, ..cfg() },
            &t,
        );
        // same chunks; bigger tiles → same pipeline depth but 16× fewer
        // arrays; energy should clearly favor fewer ADC banks
        assert!(big.arrays < small.arrays);
    }

    #[test]
    fn transposed_operand_writes_beat_naive() {
        let t = TechParams::default();
        let producer = 500.0;
        let smart = operand_write_cost(32, 17, producer, true, &t);
        let naive = operand_write_cost(32, 17, producer, false, &t);
        assert!(
            smart.latency_ns < naive.latency_ns / 1.5,
            "smart {} vs naive {}",
            smart.latency_ns,
            naive.latency_ns
        );
    }

    #[test]
    fn seq_composition_adds() {
        let a = OpCost {
            latency_ns: 10.0,
            energy_pj: 5.0,
            bottleneck_ns: 4.0,
            arrays: 2,
            setup_ns: 100.0,
            setup_pj: 1.0,
        };
        let b = OpCost {
            latency_ns: 20.0,
            energy_pj: 7.0,
            bottleneck_ns: 9.0,
            arrays: 3,
            setup_ns: 50.0,
            setup_pj: 2.0,
        };
        let c = a.seq(b);
        assert_eq!(c.latency_ns, 30.0);
        assert_eq!(c.energy_pj, 12.0);
        assert_eq!(c.bottleneck_ns, 9.0);
        assert_eq!(c.arrays, 5);
        assert_eq!(c.setup_ns, 100.0);
    }

    // ---- cost-invariant property suite (ISSUE 2 satellite) ----------
    // Drawn over the real feasible PIM space so the invariants the
    // simulator and mapper rely on hold for every searchable config.

    use crate::util::qcheck::qcheck;

    fn feasible_cfg(g: &mut crate::util::qcheck::Gen) -> PimConfig {
        let all = PimConfig::enumerate_feasible();
        *g.choose(&all)
    }

    #[test]
    fn property_batch_formula_is_monotone_and_anchored() {
        let t = TechParams::default();
        qcheck(60, |g| {
            let cfg = feasible_cfg(g);
            let k = g.usize(1, 512);
            let n = g.usize(1, 512);
            let n_vecs = g.usize(1, 48);
            let wbits = *g.choose(&[4usize, 8]);
            let c = matmul_cost(k, n, n_vecs, wbits, &cfg, &t);
            crate::prop_assert!(c.latency_ns >= 0.0 && c.energy_pj >= 0.0);
            crate::prop_assert!(c.bottleneck_ns >= 0.0 && c.arrays >= 1);
            crate::prop_assert!(
                (c.batch_ns(1) - c.latency_ns).abs() < 1e-12,
                "B=1 must cost the raw latency"
            );
            let b1 = g.usize(1, 256);
            let b2 = g.usize(b1, 512);
            crate::prop_assert!(
                c.batch_ns(b1) <= c.batch_ns(b2) + 1e-9,
                "batch cost not monotone: B{b1}={} B{b2}={}",
                c.batch_ns(b1),
                c.batch_ns(b2)
            );
            Ok(())
        });
    }

    #[test]
    fn property_matmul_monotone_in_rows_cols_bits() {
        let t = TechParams::default();
        qcheck(60, |g| {
            let cfg = feasible_cfg(g);
            let k = g.usize(1, 384);
            let n = g.usize(1, 384);
            let n_vecs = g.usize(1, 32);
            let base = matmul_cost(k, n, n_vecs, 4, &cfg, &t);
            // more rows (K): same pipeline, more silicon + energy
            let more_k = matmul_cost(k + g.usize(1, 256), n, n_vecs, 4, &cfg, &t);
            crate::prop_assert!(more_k.arrays >= base.arrays);
            crate::prop_assert!(more_k.energy_pj >= base.energy_pj - 1e-9);
            crate::prop_assert!(more_k.latency_ns >= base.latency_ns - 1e-9);
            // more cols (N): longer cycles, more conversions, more tiles
            let more_n = matmul_cost(k, n + g.usize(1, 256), n_vecs, 4, &cfg, &t);
            crate::prop_assert!(more_n.arrays >= base.arrays);
            crate::prop_assert!(more_n.energy_pj >= base.energy_pj - 1e-9);
            crate::prop_assert!(more_n.latency_ns >= base.latency_ns - 1e-9);
            // more weight bits: more planes → more silicon + energy at
            // identical pipeline latency
            let w8 = matmul_cost(k, n, n_vecs, 8, &cfg, &t);
            crate::prop_assert!(w8.arrays >= base.arrays);
            crate::prop_assert!(w8.energy_pj >= base.energy_pj - 1e-9);
            crate::prop_assert!((w8.latency_ns - base.latency_ns).abs() < 1e-9);
            Ok(())
        });
    }

    #[test]
    fn property_seq_composition_laws() {
        qcheck(80, |g| {
            let mk = |g: &mut crate::util::qcheck::Gen| OpCost {
                latency_ns: g.f64(0.0, 1e4),
                energy_pj: g.f64(0.0, 1e5),
                bottleneck_ns: g.f64(0.0, 1e4),
                arrays: g.usize(0, 64),
                setup_ns: g.f64(0.0, 1e5),
                setup_pj: g.f64(0.0, 1e5),
            };
            let a = mk(g);
            let b = mk(g);
            let c = a.seq(b);
            crate::prop_assert!(
                (c.latency_ns - (a.latency_ns + b.latency_ns)).abs() < 1e-9
            );
            crate::prop_assert!(
                (c.energy_pj - (a.energy_pj + b.energy_pj)).abs() < 1e-9
            );
            crate::prop_assert!(
                (c.bottleneck_ns - a.bottleneck_ns.max(b.bottleneck_ns)).abs()
                    < 1e-12
            );
            // the chain is never faster than either stage at any batch
            let bb = g.usize(1, 64);
            crate::prop_assert!(c.batch_ns(bb) >= a.batch_ns(bb) - 1e-9);
            crate::prop_assert!(c.batch_ns(bb) >= b.batch_ns(bb) - 1e-9);
            Ok(())
        });
    }

    #[test]
    fn property_operand_read_monotone_in_reads() {
        let t = TechParams::default();
        qcheck(40, |g| {
            let cfg = feasible_cfg(g);
            let d = g.usize(1, 128);
            let cols = g.usize(1, 128);
            let r1 = g.usize(1, 64);
            let r2 = r1 + g.usize(0, 64);
            let a = operand_read_cost(d, cols, r1, &cfg, &t);
            let b = operand_read_cost(d, cols, r2, &cfg, &t);
            crate::prop_assert!(b.latency_ns >= a.latency_ns - 1e-9);
            crate::prop_assert!(b.energy_pj >= a.energy_pj - 1e-9);
            Ok(())
        });
    }

    #[test]
    fn operand_read_scales_with_reads() {
        let t = TechParams::default();
        let r1 = operand_read_cost(32, 17, 1, &cfg(), &t);
        let r17 = operand_read_cost(32, 17, 17, &cfg(), &t);
        assert!((r17.latency_ns / r1.latency_ns - 17.0).abs() < 1e-9);
    }
}
