//! Operator→PIM mapping engine (paper §3.2, Figs. 3–4).
//!
//! Turns a genome into a DAG of `MappedOp`s with per-inference costs and
//! silicon (tile) requirements. Two styles:
//!
//! * `MapStyle::Smart` — the paper's mappings: transposed-write FM
//!   arrays, producer-overlapped DP operand programming, concurrent
//!   Σx / Σx² reductions, MBSA squaring.
//! * `MapStyle::Naive` — what Table 3's "NASRec" row measures: the same
//!   model dropped onto crossbars without the dedicated engines (buffer
//!   + row-serial operand writes, serialized reductions, square via an
//!   extra crossbar program+read).

use super::cost::{matmul_cost, operand_read_cost, operand_write_cost, OpCost};
use crate::nas::genome::{DenseOp, Genome, Interaction, SparseOp, DSI_FEATURES};
use crate::pim::{EngineKind, PimConfig, TechParams, Tile, TileSpec};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MapStyle {
    Smart,
    Naive,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OpKind {
    Fc,
    Efc,
    Dsi,
    DpEngine,
    FmEngine,
    FinalFc,
}

/// One mapped operator (node of the execution DAG).
#[derive(Clone, Debug)]
pub struct MappedOp {
    pub id: usize,
    pub name: String,
    pub kind: OpKind,
    pub engine: EngineKind,
    pub cost: OpCost,
    pub deps: Vec<usize>,
    /// bytes entering/leaving this op over the NoC (priced into sim)
    pub bytes_in: usize,
    pub bytes_out: usize,
}

/// A fully mapped model: the execution DAG + priced silicon.
#[derive(Clone, Debug)]
pub struct MappedModel {
    pub genome_name: String,
    pub dataset: String,
    pub style: MapStyle,
    pub ops: Vec<MappedOp>,
    pub tiles: Vec<Tile>,
    pub area_mm2: f64,
    pub leakage_mw: f64,
    pub total_arrays: usize,
    pub setup_ns: f64,
    pub setup_pj: f64,
}

impl MappedModel {
    /// DAG critical-path latency for batch size 1 (no resource
    /// contention; the simulator refines this with engines/queues).
    pub fn critical_path_ns(&self) -> f64 {
        let mut done = vec![0f64; self.ops.len()];
        for (i, op) in self.ops.iter().enumerate() {
            let start = op
                .deps
                .iter()
                .map(|&d| done[d])
                .fold(0f64, f64::max);
            done[i] = start + op.cost.latency_ns;
        }
        done.iter().copied().fold(0.0, f64::max)
    }

    /// Total per-inference energy (pJ).
    pub fn energy_pj(&self) -> f64 {
        self.ops.iter().map(|o| o.cost.energy_pj).sum()
    }

    /// Slowest single op — the batch-pipelining bottleneck.
    pub fn bottleneck_ns(&self) -> f64 {
        self.ops
            .iter()
            .map(|o| o.cost.bottleneck_ns)
            .fold(0.0, f64::max)
    }
}

struct Builder<'a> {
    tech: &'a TechParams,
    cfg: PimConfig,
    ops: Vec<MappedOp>,
    tiles: Vec<Tile>,
}

impl<'a> Builder<'a> {
    fn push(
        &mut self,
        name: String,
        kind: OpKind,
        engine: EngineKind,
        cost: OpCost,
        deps: Vec<usize>,
        bytes_in: usize,
        bytes_out: usize,
        mbsa_lanes: usize,
    ) -> usize {
        let id = self.ops.len();
        self.tiles.push(Tile::build(
            TileSpec {
                kind: engine,
                cfg: self.cfg,
                n_arrays: cost.arrays.max(1),
                in_buf_bytes: bytes_in.max(1024),
                out_buf_bytes: bytes_out.max(1024),
                mbsa_lanes,
            },
            self.tech,
        ));
        self.ops.push(MappedOp {
            id,
            name,
            kind,
            engine,
            cost,
            deps,
            bytes_in,
            bytes_out,
        });
        id
    }
}

/// Structural cache key for a genome: hashes everything the evaluation
/// pipeline consumes — `map_genome` and `simulate` (dataset, blocks,
/// connections, precisions, PIM genome) plus the surrogate's features —
/// and deliberately EXCLUDES `name`, so two search children with
/// identical structure share one evaluation (`nas::cache::EvalCache`).
pub fn genome_eval_key(g: &Genome) -> u64 {
    g.structural_hash()
}

/// Map a genome onto PIM hardware.
pub fn map_genome(
    g: &Genome,
    tech: &TechParams,
    style: MapStyle,
) -> crate::Result<MappedModel> {
    g.validate()?;
    let shapes = g.shapes()?;
    let d = g.d_emb;
    let mut b = Builder {
        tech,
        cfg: g.pim,
        ops: Vec::new(),
        tiles: Vec::new(),
    };
    // Producer op ids per source index (None = raw input / identity).
    let mut dense_prod: Vec<Option<usize>> = vec![None];
    let mut sparse_prod: Vec<Option<usize>> = vec![None];

    for (i, (blk, sh)) in g.blocks.iter().zip(&shapes).enumerate() {
        let dense_deps: Vec<usize> =
            blk.dense_in.iter().filter_map(|&j| dense_prod[j]).collect();
        let sparse_deps: Vec<usize> =
            blk.sparse_in.iter().filter_map(|&j| sparse_prod[j]).collect();

        // ---- dense branch -------------------------------------------------
        let dense_id = match blk.dense_op {
            DenseOp::Fc => {
                let cost = matmul_cost(sh.din, sh.dout, 1, blk.dense_wbits, &g.pim, tech);
                b.push(
                    format!("block{i}/fc"),
                    OpKind::Fc,
                    EngineKind::Mvm,
                    cost,
                    dense_deps.clone(),
                    sh.din,
                    sh.dout,
                    0,
                )
            }
            DenseOp::Dp => {
                // §3.2: FC din→d ∥ EFC nin→k; program Xᵀ; Gram reads; FC out.
                let k = Genome::dp_rows(sh.dout);
                let fc_in = matmul_cost(sh.din, d, 1, blk.dense_wbits, &g.pim, tech);
                let efc = matmul_cost(sh.nin, k, d, blk.dense_wbits, &g.pim, tech);
                // producer latency the operand writes overlap with:
                let producer = if style == MapStyle::Smart {
                    fc_in.latency_ns.max(efc.latency_ns)
                } else {
                    fc_in.latency_ns + efc.latency_ns
                };
                let write = operand_write_cost(
                    d,
                    k + 1,
                    producer,
                    style == MapStyle::Smart,
                    tech,
                );
                let reads = operand_read_cost(d, k + 1, k + 1, &g.pim, tech);
                let npairs = (k + 1) * k / 2;
                let fc_out =
                    matmul_cost(npairs, sh.dout, 1, blk.dense_wbits, &g.pim, tech);
                // fc_in/efc costs are folded into `write.latency` via the
                // producer overlap; energy/arrays still accrue.
                let mut cost = write.seq(reads).seq(fc_out);
                cost.energy_pj += fc_in.energy_pj + efc.energy_pj;
                cost.arrays += fc_in.arrays + efc.arrays;
                cost.setup_ns = cost.setup_ns.max(fc_in.setup_ns).max(efc.setup_ns);
                cost.setup_pj += fc_in.setup_pj + efc.setup_pj;
                let mut deps = dense_deps.clone();
                deps.extend(sparse_deps.iter().copied());
                deps.dedup();
                b.push(
                    format!("block{i}/dp"),
                    OpKind::DpEngine,
                    EngineKind::Dp,
                    cost,
                    deps,
                    sh.din + sh.nin * d,
                    sh.dout,
                    0,
                )
            }
        };
        let mut dense_out_id = dense_id;

        // ---- sparse branch ------------------------------------------------
        let sparse_id = match blk.sparse_op {
            SparseOp::Efc => {
                let cost = matmul_cost(
                    sh.nin,
                    blk.sparse_features,
                    d,
                    blk.sparse_wbits,
                    &g.pim,
                    tech,
                );
                Some(b.push(
                    format!("block{i}/efc"),
                    OpKind::Efc,
                    EngineKind::Mvm,
                    cost,
                    sparse_deps.clone(),
                    sh.nin * d,
                    blk.sparse_features * d,
                    0,
                ))
            }
            SparseOp::Identity => {
                // pass-through: inherits the producers directly
                None
            }
        };
        let mut sparse_out_id = sparse_id.or_else(|| sparse_deps.first().copied());

        // ---- interaction --------------------------------------------------
        match blk.interaction {
            Interaction::None => {}
            Interaction::Fm => {
                // sparse → dense merger (transposed array + MBSA + FC)
                let n_vecs = match blk.sparse_op {
                    SparseOp::Efc => blk.sparse_features,
                    SparseOp::Identity => sh.nin,
                };
                let producer_ns = sparse_id
                    .map(|sid| b.ops[sid].cost.latency_ns)
                    .unwrap_or(0.0);
                let write = operand_write_cost(
                    d,
                    n_vecs,
                    if style == MapStyle::Smart { producer_ns } else { producer_ns },
                    style == MapStyle::Smart,
                    tech,
                );
                let cycle = super::cost::cycle_time_ns(&g.pim, tech, d.min(g.pim.xbar));
                let chunks = g.pim.n_chunks() as f64;
                let (reduce_ns, extra_pj) = if style == MapStyle::Smart {
                    // Σx (1 read) ∥ Σx² (n reads) concurrent + MBSA square
                    let reads = (n_vecs as f64).max(1.0) * chunks * cycle;
                    let mbsa = g.pim.x_bits as f64 * tech.mbsa_cycle_ns;
                    (reads + mbsa, d as f64 * g.pim.x_bits as f64 * tech.mbsa_lane_pj)
                } else {
                    // serialized: Σx then Σx², square via extra program+read
                    let reads = (1.0 + n_vecs as f64) * chunks * cycle;
                    let square =
                        tech.write_pulse_ns + chunks * cycle;
                    (reads + square, tech.cell_write_pj * d as f64)
                };
                let fc = matmul_cost(d, sh.dout, 1, blk.inter_wbits, &g.pim, tech);
                let mut cost = write.seq(fc);
                cost.latency_ns += reduce_ns;
                cost.energy_pj += extra_pj
                    + (n_vecs as f64 + 1.0)
                        * chunks
                        * tech.xbar_read_cycle(d, n_vecs, g.pim.dac_bits).energy_pj;
                let mut deps: Vec<usize> = vec![dense_out_id];
                if let Some(sid) = sparse_out_id {
                    deps.push(sid);
                }
                let fm_id = b.push(
                    format!("block{i}/fm"),
                    OpKind::FmEngine,
                    EngineKind::Fm,
                    cost,
                    deps,
                    n_vecs * d,
                    sh.dout,
                    d,
                );
                dense_out_id = fm_id; // dense output now includes the merge
            }
            Interaction::Dsi => {
                // dense → sparse merger: FC + reshape
                let cost = matmul_cost(
                    sh.dout,
                    DSI_FEATURES * d,
                    1,
                    blk.inter_wbits,
                    &g.pim,
                    tech,
                );
                let dsi_id = b.push(
                    format!("block{i}/dsi"),
                    OpKind::Dsi,
                    EngineKind::Mvm,
                    cost,
                    vec![dense_out_id],
                    sh.dout,
                    DSI_FEATURES * d,
                    0,
                );
                // sparse output now depends on both branches
                sparse_out_id = Some(dsi_id);
            }
        }

        dense_prod.push(Some(dense_out_id));
        sparse_prod.push(sparse_out_id);
    }

    // ---- final FC ---------------------------------------------------------
    let last = g.blocks.len();
    let final_cost = matmul_cost(
        shapes[last - 1].dout,
        1,
        1,
        g.final_wbits,
        &g.pim,
        tech,
    );
    let final_dep = dense_prod[last].into_iter().collect();
    b.push(
        "final/fc".to_string(),
        OpKind::FinalFc,
        EngineKind::Mvm,
        final_cost,
        final_dep,
        shapes[last - 1].dout,
        1,
        0,
    );

    let area_mm2 = b.tiles.iter().map(|t| t.area_mm2).sum();
    let leakage_mw = b.tiles.iter().map(|t| t.leakage_mw).sum();
    let total_arrays = b.ops.iter().map(|o| o.cost.arrays).sum();
    let setup_ns = b.ops.iter().map(|o| o.cost.setup_ns).fold(0.0, f64::max);
    let setup_pj = b.ops.iter().map(|o| o.cost.setup_pj).sum();
    Ok(MappedModel {
        genome_name: g.name.clone(),
        dataset: g.dataset.clone(),
        style,
        ops: b.ops,
        tiles: b.tiles,
        area_mm2,
        leakage_mw,
        total_arrays,
        setup_ns,
        setup_pj,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::genome::{autorac_best, nasrec_like};

    #[test]
    fn maps_reference_genomes() {
        let tech = TechParams::default();
        for ds in ["criteo", "avazu", "kdd"] {
            let g = autorac_best(ds);
            let m = map_genome(&g, &tech, MapStyle::Smart).unwrap();
            assert!(!m.ops.is_empty());
            assert!(m.area_mm2 > 0.0);
            assert!(m.critical_path_ns() > 0.0);
            assert!(m.energy_pj() > 0.0);
        }
    }

    #[test]
    fn smart_mapping_beats_naive_mapping() {
        // The Table 3 "vs NASRec (naive)" effect: same genome, different
        // mapping style → smart is strictly faster.
        let tech = TechParams::default();
        let g = nasrec_like("criteo");
        let smart = map_genome(&g, &tech, MapStyle::Smart).unwrap();
        let naive = map_genome(&g, &tech, MapStyle::Naive).unwrap();
        // Mapping-style-only ablation (same genome, same PIM config).
        // Table 3's full 3.17× additionally compounds the searched model
        // and PIM config — regenerated by `cargo bench --bench table3`.
        assert!(
            naive.critical_path_ns() > 1.3 * smart.critical_path_ns(),
            "naive {} vs smart {}",
            naive.critical_path_ns(),
            smart.critical_path_ns()
        );
    }

    #[test]
    fn dag_dependencies_are_acyclic_and_in_range() {
        let tech = TechParams::default();
        let m = map_genome(&autorac_best("criteo"), &tech, MapStyle::Smart).unwrap();
        for op in &m.ops {
            for &d in &op.deps {
                assert!(d < op.id, "{}: dep {d} not earlier", op.name);
            }
        }
    }

    #[test]
    fn final_fc_is_last_and_depends_on_last_block() {
        let tech = TechParams::default();
        let m = map_genome(&autorac_best("criteo"), &tech, MapStyle::Smart).unwrap();
        let last = m.ops.last().unwrap();
        assert_eq!(last.kind, OpKind::FinalFc);
        assert!(!last.deps.is_empty());
    }

    #[test]
    fn four_bit_genome_uses_less_area() {
        let tech = TechParams::default();
        let g8 = nasrec_like("criteo"); // all 8-bit
        let mut g4 = g8.clone();
        for b in &mut g4.blocks {
            b.dense_wbits = 4;
            b.sparse_wbits = 4;
            b.inter_wbits = 4;
        }
        let m8 = map_genome(&g8, &tech, MapStyle::Smart).unwrap();
        let m4 = map_genome(&g4, &tech, MapStyle::Smart).unwrap();
        assert!(m4.area_mm2 < m8.area_mm2);
        assert!(m4.total_arrays < m8.total_arrays);
    }

    #[test]
    fn mapped_model_reports_setup_costs() {
        let tech = TechParams::default();
        let m = map_genome(&autorac_best("criteo"), &tech, MapStyle::Smart).unwrap();
        assert!(m.setup_ns > 0.0);
        assert!(m.setup_pj > 0.0);
    }

    #[test]
    fn eval_key_ignores_name_but_nothing_else() {
        let a = autorac_best("criteo");
        let mut renamed = a.clone();
        renamed.name = "g17c3".to_string();
        assert_ne!(a.hash(), renamed.hash(), "full hash covers the name");
        assert_eq!(genome_eval_key(&a), genome_eval_key(&renamed));
        // any structural field must change the key
        let mut bits = a.clone();
        bits.blocks[2].dense_wbits = 8;
        assert_ne!(genome_eval_key(&a), genome_eval_key(&bits));
        let mut pim = a.clone();
        pim.pim.adc_bits = 6;
        assert_ne!(genome_eval_key(&a), genome_eval_key(&pim));
        let mut ds = a.clone();
        ds.dataset = "avazu".to_string();
        assert_ne!(genome_eval_key(&a), genome_eval_key(&ds));
    }
}
