//! Baseline accelerator models (S13) for Table 3: CPU roofline, RecNMP
//! near-memory processing, and the hand-crafted ReREC PIM design.

pub mod cpu;
pub mod recnmp;
pub mod rerec;
pub mod workload;

pub use cpu::CpuModel;
pub use recnmp::RecNmpModel;
pub use rerec::{rerec_genome, rerec_model};
pub use workload::{genome_stats, genome_stats_pooled, WorkloadStats, TABLE3_POOLING};
