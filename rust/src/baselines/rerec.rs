//! ReREC baseline (Wang et al., ICCAD'21) — in-ReRAM recommender
//! acceleration with access-aware embedding mapping.
//!
//! ReREC is the strongest comparator: a hand-optimized PIM design with
//! the access-aware embedding placement (it introduced the idea the
//! paper's memory tiles adopt) and a competent crossbar dataflow for a
//! DLRM-style fixed model. What it lacks is everything AutoRAC searches:
//! mixed per-operator precision (ReREC maps 8-bit everywhere), model
//! topology tuned to the PIM dataflow, and the ReRAM configuration
//! itself. We therefore model ReREC as the *smart* mapping of a fixed
//! DLRM-like genome at uniform 8-bit on a fixed (64, 1, 1, 8) array —
//! hand-crafted quality, no co-design.

use crate::mapping::{map_genome, MapStyle, MappedModel};
use crate::nas::genome::{Block, DenseOp, Genome, Interaction, SparseOp};
use crate::pim::{PimConfig, TechParams};

/// The fixed DLRM-like architecture ReREC accelerates.
pub fn rerec_genome(dataset: &str) -> Genome {
    use DenseOp::*;
    use Interaction::*;
    use SparseOp::*;
    let b = |dense_op, dense_dim, sparse_op, interaction,
             dense_in: &[usize], sparse_in: &[usize]| Block {
        dense_op,
        dense_dim,
        dense_wbits: 8,
        sparse_op,
        sparse_features: 16,
        sparse_wbits: 8,
        interaction,
        inter_wbits: 8,
        dense_in: dense_in.to_vec(),
        sparse_in: sparse_in.to_vec(),
    };
    Genome {
        name: format!("rerec-{dataset}"),
        dataset: dataset.to_string(),
        d_emb: 32,
        blocks: vec![
            // bottom MLP
            b(Fc, 512, Identity, None, &[0], &[0]),
            b(Fc, 256, Identity, None, &[1], &[1]),
            // pairwise interaction over fields (DLRM's dot interaction)
            b(Dp, 256, Identity, None, &[2], &[2]),
            // top MLP
            b(Fc, 512, Identity, None, &[3], &[3]),
            b(Fc, 256, Identity, None, &[4], &[4]),
            b(Fc, 128, Identity, None, &[5], &[5]),
            b(Fc, 64, Identity, None, &[6], &[6]),
        ],
        final_wbits: 8,
        pim: PimConfig {
            xbar: 64,
            dac_bits: 1,
            cell_bits: 1,
            adc_bits: 8,
            ..PimConfig::default()
        },
    }
}

/// Map the ReREC design (smart mapping — it is hand-optimized).
pub fn rerec_model(dataset: &str, tech: &TechParams) -> crate::Result<MappedModel> {
    map_genome(&rerec_genome(dataset), tech, MapStyle::Smart)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::genome::autorac_best;
    use crate::sim::{simulate, Workload};

    #[test]
    fn rerec_is_competitive_but_loses_to_autorac() {
        let tech = TechParams::default();
        let rerec = rerec_model("criteo", &tech).unwrap();
        let autorac =
            map_genome(&autorac_best("criteo"), &tech, MapStyle::Smart).unwrap();
        let wl = Workload::default();
        let r_rerec = simulate(&rerec, None, &wl);
        let r_auto = simulate(&autorac, None, &wl);
        let speedup = r_auto.speedup_vs(&r_rerec);
        // paper: 1.28× — a modest but real gap
        assert!(
            speedup > 1.0 && speedup < 8.0,
            "autorac vs rerec speedup {speedup}"
        );
        assert!(r_auto.power_eff_vs(&r_rerec) > 1.0);
    }

    #[test]
    fn rerec_genome_validates() {
        rerec_genome("criteo").validate().unwrap();
    }
}
