//! CPU baseline — roofline cost model of recommender inference on a
//! Xeon-class server (the paper's reference is an Intel Xeon Gold 6254:
//! 18 cores, 3.1 GHz, AVX-512, 6-channel DDR4-2933).
//!
//! Recommender inference at small batch is memory-bound twice over:
//! embedding gathers are random DRAM reads (no locality by design —
//! that's what zipf-striped tables look like after hashing), and GEMV
//! weights stream from DRAM with no reuse. The roofline therefore takes
//! `max(compute, weight-stream, gather)` per inference plus a fixed
//! software overhead — the structure that produces the paper's ~20×
//! PIM-vs-CPU gap.

use super::workload::WorkloadStats;
use crate::sim::SimReport;

#[derive(Clone, Copy, Debug)]
pub struct CpuModel {
    /// peak fused MAC throughput (GMAC/s) across cores
    pub peak_gmacs: f64,
    /// streaming DRAM bandwidth (GB/s)
    pub stream_gbs: f64,
    /// effective random-access bandwidth for gathers (GB/s)
    pub random_gbs: f64,
    /// per-gather latency when latency-bound (ns)
    pub gather_ns: f64,
    /// gathers the memory system keeps in flight
    pub gather_mlp: f64,
    /// software + framework overhead per inference (ns)
    pub sw_overhead_ns: f64,
    /// active package power (W)
    pub power_w: f64,
    /// die area (mm²) — informational (Table 3 has no CPU area row)
    pub area_mm2: f64,
}

impl Default for CpuModel {
    fn default() -> Self {
        CpuModel {
            peak_gmacs: 900.0,   // 18c × 3.1GHz × 16 f32 MAC/clk ≈ 893
            stream_gbs: 110.0,   // 6 × DDR4-2933
            random_gbs: 10.0,    // ~64B lines at random-access efficiency
            gather_ns: 75.0,
            gather_mlp: 10.0,
            // framework/dispatch overhead of batch-1 online inference
            // (PyTorch-style serving stacks measure in the µs–ms range)
            sw_overhead_ns: 8000.0,
            power_w: 105.0,      // sustained package power under load
            area_mm2: 485.0,
        }
    }
}

impl CpuModel {
    /// Per-inference latency (ns) for batch size 1.
    pub fn latency_ns(&self, w: &WorkloadStats) -> f64 {
        let compute = w.macs / self.peak_gmacs; // GMAC/s ⇒ ns
        let weights = w.weight_bytes / self.stream_gbs;
        let gather_bw = (w.gathers * w.row_bytes) as f64 / self.random_gbs;
        let gather_lat = w.gathers as f64 * self.gather_ns / self.gather_mlp;
        compute.max(weights) + gather_bw.max(gather_lat) + self.sw_overhead_ns
    }

    /// Batched throughput: weights amortize across the batch, gathers do
    /// not. Returns inferences / second at the given batch size.
    pub fn throughput_rps(&self, w: &WorkloadStats, batch: usize) -> f64 {
        let b = batch as f64;
        let compute = w.macs * b / self.peak_gmacs;
        let weights = w.weight_bytes / self.stream_gbs; // one stream per batch
        let gathers = (w.gathers * w.row_bytes) as f64 * b / self.random_gbs;
        let total_ns = compute.max(weights) + gathers + self.sw_overhead_ns;
        b / (total_ns / 1e9)
    }

    pub fn report(&self, w: &WorkloadStats, batch: usize) -> SimReport {
        let throughput = self.throughput_rps(w, batch);
        let latency = self.latency_ns(w);
        SimReport {
            design: "cpu-xeon6254".to_string(),
            n_requests: batch,
            latency_ns_mean: latency,
            latency_ns_p99: latency * 1.4,
            throughput_rps: throughput,
            energy_pj_per_inf: self.power_w * 1e12 / throughput.max(1e-9),
            power_mw: self.power_w * 1e3,
            area_mm2: self.area_mm2,
            mem_area_mm2: 0.0,
            inf_per_s_per_w: throughput / self.power_w,
            makespan_ns: batch as f64 / throughput * 1e9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::workload::genome_stats;
    use crate::nas::genome::autorac_best;

    #[test]
    fn cpu_is_memory_bound_at_batch_one() {
        let cpu = CpuModel::default();
        let w = genome_stats(&autorac_best("criteo")).unwrap();
        let compute_ns = w.macs / cpu.peak_gmacs;
        assert!(cpu.latency_ns(&w) > 2.0 * compute_ns);
    }

    #[test]
    fn batching_amortizes_weight_streams() {
        let cpu = CpuModel::default();
        let w = genome_stats(&autorac_best("criteo")).unwrap();
        let t1 = cpu.throughput_rps(&w, 1);
        let t64 = cpu.throughput_rps(&w, 64);
        assert!(t64 > 3.0 * t1, "t1={t1} t64={t64}");
    }

    #[test]
    fn report_is_consistent() {
        let cpu = CpuModel::default();
        let w = genome_stats(&autorac_best("criteo")).unwrap();
        let r = cpu.report(&w, 32);
        assert!(r.throughput_rps > 0.0);
        assert!((r.inf_per_s_per_w - r.throughput_rps / cpu.power_w).abs() < 1e-9);
    }
}
