//! RecNMP baseline (Ke et al., ISCA'20) — rank-level near-memory
//! processing for embedding operations.
//!
//! RecNMP puts lightweight gather+pooling engines on the DIMM buffer
//! chip: embedding reads exploit rank-level parallelism and a hot-entry
//! cache, cutting gather latency/energy several-fold, while the dense
//! MLP still runs on the host CPU. We model exactly that split: the
//! gather term of the CPU roofline is accelerated, everything else is
//! inherited, plus DIMM engine power.

use super::cpu::CpuModel;
use super::workload::WorkloadStats;
use crate::sim::SimReport;

#[derive(Clone, Copy, Debug)]
pub struct RecNmpModel {
    pub host: CpuModel,
    /// effective gather speedup from rank-parallelism + hot caching
    /// (the RecNMP paper reports up to 4× end-to-end embedding speedup)
    pub gather_speedup: f64,
    /// fraction of gather energy avoided (served near-memory)
    pub gather_energy_saving: f64,
    /// added DIMM-side engine power (W)
    pub dimm_power_w: f64,
}

impl Default for RecNmpModel {
    fn default() -> Self {
        RecNmpModel {
            host: CpuModel::default(),
            gather_speedup: 6.5,
            gather_energy_saving: 0.45,
            dimm_power_w: 6.0,
        }
    }
}

impl RecNmpModel {
    pub fn throughput_rps(&self, w: &WorkloadStats, batch: usize) -> f64 {
        let b = batch as f64;
        let h = &self.host;
        let compute = w.macs * b / h.peak_gmacs;
        let weights = w.weight_bytes / h.stream_gbs;
        let gathers =
            (w.gathers * w.row_bytes) as f64 * b / h.random_gbs / self.gather_speedup;
        let total_ns = compute.max(weights) + gathers + h.sw_overhead_ns;
        b / (total_ns / 1e9)
    }

    pub fn report(&self, w: &WorkloadStats, batch: usize) -> SimReport {
        let throughput = self.throughput_rps(w, batch);
        let h = &self.host;
        let gather_frac = {
            // crude attribution of package power to the gather stream
            let base = h.report(w, batch);
            let _ = base;
            0.35
        };
        let power_w = h.power_w * (1.0 - gather_frac * self.gather_energy_saving)
            + self.dimm_power_w;
        let latency = 1e9 / self.throughput_rps(w, 1);
        SimReport {
            design: "recnmp".to_string(),
            n_requests: batch,
            latency_ns_mean: latency,
            latency_ns_p99: latency * 1.4,
            throughput_rps: throughput,
            energy_pj_per_inf: power_w * 1e12 / throughput.max(1e-9),
            power_mw: power_w * 1e3,
            area_mm2: h.area_mm2, // host die; DIMM engines negligible
            mem_area_mm2: 0.0,
            inf_per_s_per_w: throughput / power_w,
            makespan_ns: batch as f64 / throughput * 1e9,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::workload::genome_stats;
    use crate::nas::genome::autorac_best;

    #[test]
    fn recnmp_beats_cpu_but_stays_host_bound() {
        let w = genome_stats(&autorac_best("criteo")).unwrap();
        let cpu = CpuModel::default().report(&w, 32);
        let nmp = RecNmpModel::default().report(&w, 32);
        assert!(nmp.throughput_rps > cpu.throughput_rps);
        // the MLP still runs on the host: gains are bounded well below
        // the raw gather speedup
        assert!(nmp.throughput_rps < 3.8 * cpu.throughput_rps);
        assert!(nmp.inf_per_s_per_w > cpu.inf_per_s_per_w);
    }
}
