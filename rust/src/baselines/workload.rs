//! Per-inference workload statistics of a genome — the quantities the
//! CPU / near-memory baselines price (they do not see crossbars).

use crate::data::profile;
use crate::nas::genome::{DenseOp, Genome, Interaction, SparseOp, DSI_FEATURES};

/// Arithmetic/memory footprint of one inference (batch = 1).
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkloadStats {
    /// multiply-accumulate count
    pub macs: f64,
    /// weight bytes touched (fp32 on CPU baselines)
    pub weight_bytes: f64,
    /// embedding rows gathered
    pub gathers: usize,
    /// bytes per gathered row
    pub row_bytes: usize,
    /// activation bytes moved between operators
    pub act_bytes: f64,
}

/// Production recommender embeddings are *pooled multi-hot* lookups
/// (RecNMP evaluates pooling factors 10–80); our synthetic datasets are
/// single-hot, so Table 3's workload applies this factor to the gather
/// counts on BOTH the baseline and the PIM side to restore the gather
/// pressure the comparison is about.
pub const TABLE3_POOLING: usize = 64;

/// `genome_stats` with a pooling factor applied to the gather count.
pub fn genome_stats_pooled(g: &Genome, pooling: usize) -> crate::Result<WorkloadStats> {
    let mut s = genome_stats(g)?;
    s.gathers *= pooling.max(1);
    // pooled rows are reduced (summed) as they stream: pooling adds
    // d_emb MACs per extra row
    s.macs += ((pooling.max(1) - 1) * s.row_bytes / 4) as f64;
    Ok(s)
}

/// Walk the genome graph and accumulate MACs / bytes (mirrors the shape
/// semantics of `Genome::shapes`).
pub fn genome_stats(g: &Genome) -> crate::Result<WorkloadStats> {
    let prof = profile(&g.dataset)?;
    let shapes = g.shapes()?;
    let d = g.d_emb as f64;
    let mut s = WorkloadStats {
        gathers: prof.n_sparse(),
        row_bytes: g.d_emb * 4,
        ..Default::default()
    };
    fn add_mm(s: &mut WorkloadStats, k: f64, n: f64, vecs: f64) {
        s.macs += k * n * vecs;
        s.weight_bytes += k * n * 4.0;
        s.act_bytes += (k + n) * vecs * 4.0;
    }
    for (blk, sh) in g.blocks.iter().zip(&shapes) {
        match blk.dense_op {
            DenseOp::Fc => add_mm(&mut s, sh.din as f64, sh.dout as f64, 1.0),
            DenseOp::Dp => {
                let k = Genome::dp_rows(sh.dout) as f64;
                add_mm(&mut s, sh.din as f64, d, 1.0);
                add_mm(&mut s, sh.nin as f64, k, d);
                // Gram: (k+1)² × d MACs (upper triangle read out)
                s.macs += (k + 1.0) * (k + 1.0) * d;
                s.act_bytes += (k + 1.0) * d * 4.0;
                let npairs = (k + 1.0) * k / 2.0;
                add_mm(&mut s, npairs, sh.dout as f64, 1.0);
            }
        }
        if blk.sparse_op == SparseOp::Efc {
            add_mm(&mut s, sh.nin as f64, blk.sparse_features as f64, d);
        }
        match blk.interaction {
            Interaction::Fm => {
                let n = match blk.sparse_op {
                    SparseOp::Efc => blk.sparse_features,
                    SparseOp::Identity => sh.nin,
                } as f64;
                s.macs += 2.0 * n * d; // Σx and Σx² passes
                add_mm(&mut s, d, sh.dout as f64, 1.0);
            }
            Interaction::Dsi => {
                add_mm(&mut s, sh.dout as f64, DSI_FEATURES as f64 * d, 1.0)
            }
            Interaction::None => {}
        }
    }
    add_mm(&mut s, shapes.last().unwrap().dout as f64, 1.0, 1.0);
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nas::genome::autorac_best;

    #[test]
    fn stats_are_positive_and_plausible() {
        let s = genome_stats(&autorac_best("criteo")).unwrap();
        assert!(s.macs > 1e4 && s.macs < 1e9, "{}", s.macs);
        assert!(s.weight_bytes > 1e4);
        assert_eq!(s.gathers, 26);
        assert_eq!(s.row_bytes, 128);
    }

    #[test]
    fn bigger_dims_mean_more_macs() {
        let g = autorac_best("criteo");
        let mut big = g.clone();
        for b in &mut big.blocks {
            b.dense_dim = (b.dense_dim * 2).min(1024);
        }
        assert!(
            genome_stats(&big).unwrap().macs > genome_stats(&g).unwrap().macs
        );
    }
}
