//! Quickstart: the whole stack in ~40 lines.
//!
//! 1. open the AOT artifact registry (HLO text lowered by `make
//!    artifacts` — JAX/Pallas at build time, never at run time);
//! 2. load the trained embedding tables into the memory-tile store;
//! 3. generate a few synthetic Criteo-like requests;
//! 4. gather embeddings (rust side = the paper's memory tiles) and score
//!    the batch on the PJRT CPU client through the searched AutoRAC model.
//!
//! Run: `cargo run --release --example quickstart`

use autorac::data::{profile, Generator, DEFAULT_SEED};
use autorac::embeddings::EmbeddingStore;
use autorac::runtime::atns::TensorFile;
use autorac::runtime::client::Runtime;
use std::path::Path;

fn main() -> autorac::Result<()> {
    let dir = Path::new("artifacts");
    autorac::ensure!(
        dir.join("meta.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    autorac::ensure!(
        Runtime::pjrt_available(),
        "PJRT backend not linked in this offline build (stub runtime::xla) — \
         quickstart needs artifact execution"
    );

    let prof = profile("criteo")?;
    let store = EmbeddingStore::from_atns(&TensorFile::read(
        &dir.join("embeddings_criteo.bin"),
    )?)?;
    let mut runtime = Runtime::open(dir)?;
    println!("PJRT platform: {}", runtime.platform());

    // Build a batch of 8 requests, padded to the batch-32 artifact.
    let b = 32usize;
    let nd = prof.n_dense.max(1);
    let mut gen = Generator::new(prof.clone(), DEFAULT_SEED);
    let mut dense = vec![0f32; b * nd];
    let mut sparse = vec![0f32; b * prof.n_sparse() * store.d_emb];
    for i in 0..8 {
        let (d, ids) = gen.features(i);
        dense[i * nd..i * nd + d.len()].copy_from_slice(&d);
        let ids: Vec<i32> = ids.iter().map(|&x| x as i32).collect();
        let mut row = Vec::new();
        store.gather(&ids, 1, &mut row);
        let stride = prof.n_sparse() * store.d_emb;
        sparse[i * stride..(i + 1) * stride].copy_from_slice(&row);
    }

    let probs = runtime.infer(
        "model_criteo_b32",
        &dense,
        [b, nd],
        &sparse,
        [b, prof.n_sparse(), store.d_emb],
    )?;
    for (i, p) in probs.iter().take(8).enumerate() {
        println!("request {i}: p(click) = {p:.4}");
    }
    println!("quickstart OK");
    Ok(())
}
