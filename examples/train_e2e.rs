//! End-to-end training driver — proves all three layers compose:
//!
//! the L2 train step (JAX fwd/bwd + Adagrad, embedding gather inside)
//! was AOT-lowered to HLO text at build time; this rust binary loads it,
//! generates synthetic Criteo-like batches with the shared procedural
//! dataset (bit-identical to what python training sees), and drives a
//! full training loop from rust — logging the loss curve. Python never
//! runs.
//!
//! Run: `cargo run --release --example train_e2e -- [steps]`

use autorac::data::{profile, make_batch, Generator, DEFAULT_SEED};
use autorac::runtime::atns::TensorFile;
use autorac::runtime::client::{lit_f32, lit_i32, Runtime};
use autorac::runtime::xla;
use std::path::Path;
use std::time::Instant;

fn main() -> autorac::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let dir = Path::new("artifacts");
    autorac::ensure!(
        dir.join("train_criteo.hlo.txt").exists(),
        "train artifact missing — run `make artifacts` first"
    );
    autorac::ensure!(
        Runtime::pjrt_available(),
        "PJRT backend not linked in this offline build (stub runtime::xla) — \
         train_e2e needs artifact execution"
    );

    let mut rt = Runtime::open(dir)?;
    let meta = rt
        .meta("train_criteo")
        .ok_or_else(|| autorac::err!("train_criteo not in meta.json"))?
        .clone();
    let order = meta.param_order.clone();
    let batch = meta.batch;
    autorac::ensure!(!order.is_empty(), "train meta lacks param_order");

    // Initial params + Adagrad accumulators, in feed order.
    let init = TensorFile::read(&dir.join("train_criteo_init.bin"))?;
    let mut state: Vec<xla::Literal> = Vec::with_capacity(2 * order.len());
    for prefix in ["p", "a"] {
        for name in &order {
            let t = init
                .get(&format!("{prefix}/{name}"))
                .ok_or_else(|| autorac::err!("missing init tensor {prefix}/{name}"))?;
            let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
            state.push(lit_f32(&t.as_f32()?, &dims)?);
        }
    }
    println!(
        "train_e2e: {} params ({} tensors incl. accumulators), batch {batch}, {steps} steps",
        order.len(),
        state.len()
    );

    let prof = profile("criteo")?;
    let nd = prof.n_dense.max(1);
    let mut gen = Generator::new(prof.clone(), DEFAULT_SEED);
    let t0 = Instant::now();
    let mut first_losses = Vec::new();
    let mut last_losses = Vec::new();
    rt.ensure_compiled("train_criteo")?;
    println!("compiled train step in {:.1}s", t0.elapsed().as_secs_f64());

    let t_train = Instant::now();
    for step in 0..steps {
        let b = make_batch(&mut gen, step * batch, batch);
        let mut inputs = std::mem::take(&mut state);
        inputs.push(lit_f32(&b.dense, &[batch as i64, nd as i64])?);
        inputs.push(lit_i32(&b.ids, &[batch as i64, prof.n_sparse() as i64])?);
        inputs.push(lit_f32(&b.labels, &[batch as i64])?);
        let mut outs = rt.execute("train_criteo", &inputs)?;
        let loss_lit = outs.pop().expect("loss output");
        let loss = loss_lit.to_vec::<f32>()?[0];
        state = outs; // new params + accumulators feed the next step
        if step < 10 {
            first_losses.push(loss);
        }
        if step >= steps.saturating_sub(10) {
            last_losses.push(loss);
        }
        if step % 20 == 0 || step == steps - 1 {
            println!(
                "  step {step:>4}  loss {loss:.4}   ({:.0} ms/step)",
                t_train.elapsed().as_millis() as f64 / (step + 1) as f64
            );
        }
    }
    let first: f32 = first_losses.iter().sum::<f32>() / first_losses.len() as f32;
    let last: f32 = last_losses.iter().sum::<f32>() / last_losses.len() as f32;
    println!(
        "loss {first:.4} → {last:.4} over {steps} steps ({:.1}s total)",
        t_train.elapsed().as_secs_f64()
    );
    autorac::ensure!(
        last < first,
        "training did not reduce the loss ({first} → {last})"
    );
    println!("train_e2e OK — rust-driven training converges");
    Ok(())
}
