//! Serving scenario: batched online CTR scoring through the coordinator.
//!
//! Exercises the full L3 request path — router → dynamic batcher →
//! embedding memory tiles (gather) → PJRT execution of the AOT model —
//! under an open-loop load, and reports latency/throughput the way a
//! serving system would.
//!
//! Run: `cargo run --release --example serve_ctr -- [requests] [rps]`

use autorac::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, PjrtEngine, Request,
};
use autorac::data::{profile, Generator, DEFAULT_SEED};
use autorac::embeddings::EmbeddingStore;
use autorac::runtime::atns::TensorFile;
use autorac::runtime::client::Runtime;
use std::path::PathBuf;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

fn main() -> autorac::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = argv.first().and_then(|s| s.parse().ok()).unwrap_or(2000);
    let rps: f64 = argv.get(1).and_then(|s| s.parse().ok()).unwrap_or(5000.0);

    let dir = PathBuf::from("artifacts");
    autorac::ensure!(
        dir.join("meta.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    autorac::ensure!(
        Runtime::pjrt_available(),
        "PJRT backend not linked in this offline build (stub runtime::xla) — \
         serve_ctr needs artifact execution"
    );
    let prof = profile("criteo")?;
    let store = Arc::new(EmbeddingStore::from_atns(&TensorFile::read(
        &dir.join("embeddings_criteo.bin"),
    )?)?);
    let (nd, ns, d) = (prof.n_dense, prof.n_sparse(), store.d_emb);

    let dir2 = dir.clone();
    let coord = Coordinator::start(
        CoordinatorConfig {
            n_workers: 1,
            batcher: BatcherConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(2),
            },
            ..Default::default()
        },
        store,
        move |_| {
            let rt = Runtime::open(&dir2)?;
            Ok(Box::new(PjrtEngine::new(rt, "criteo", 32, nd, ns, d)?))
        },
    )?;

    println!("open-loop load: {n} requests at {rps:.0} req/s");
    let mut gen = Generator::new(prof, DEFAULT_SEED);
    let (tx, rx) = mpsc::channel();
    let t0 = Instant::now();
    let gap_ns = 1e9 / rps;
    for id in 0..n {
        let target = (id as f64 * gap_ns) as u64;
        let now = t0.elapsed().as_nanos() as u64;
        if now < target {
            std::thread::sleep(Duration::from_nanos(target - now));
        }
        let (dense, ids) = gen.features(id);
        coord.submit(Request::full(
            id as u64,
            dense,
            ids.iter().map(|&x| x as i32).collect(),
            tx.clone(),
        ))?;
    }
    drop(tx);
    let responses: Vec<_> = rx.iter().collect();
    autorac::ensure!(responses.len() == n, "lost responses");
    let snap = coord.metrics.snapshot();
    coord.shutdown();

    println!("served {} responses in {:.2}s", n, snap.elapsed_s);
    println!("  throughput  {:.0} req/s", snap.throughput_rps);
    println!("  mean batch  {:.1} ({} batches)", snap.mean_batch, snap.batches);
    println!("  e2e p50     {:.0} µs", snap.e2e_p50_us);
    println!("  e2e p99     {:.0} µs", snap.e2e_p99_us);
    println!("  exec p50    {:.0} µs (PJRT batch execution)", snap.exec_p50_us);
    let mean: f64 = responses.iter().map(|r| r.prob as f64).sum::<f64>() / n as f64;
    println!("  mean p(click) {mean:.4}");
    Ok(())
}
