//! Co-design search scenario: run a (reduced) Algorithm-1 evolutionary
//! search on the parallel engine and compare the discovered design
//! against the hand-crafted NASRec reference on the behavioral
//! simulator — the paper's core loop, saturating every core (S20).
//!
//! Run: `cargo run --release --example codesign_search -- [generations] [workers]`
//! (240 generations ≈ the paper's full run; default 60 keeps this quick;
//! workers defaults to every hardware thread — the result is
//! bit-identical for ANY worker count, see tests/search_determinism.rs)

use autorac::mapping::{map_genome, MapStyle};
use autorac::nas::{nasrec_like, ParallelSearch, SearchConfig, Surrogate};
use autorac::pim::TechParams;
use autorac::sim::{simulate, Workload};
use std::time::Instant;

fn main() -> autorac::Result<()> {
    let generations: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);
    let workers: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(SearchConfig::all_cores);

    let cfg = SearchConfig {
        dataset: "criteo".to_string(),
        generations,
        workers,
        ..SearchConfig::default()
    };
    println!(
        "co-search: {} generations × {} children (population {}) on {} worker(s)",
        cfg.generations, cfg.children_per_gen, cfg.population, cfg.workers
    );
    let t0 = Instant::now();
    let mut search = ParallelSearch::new(cfg, Surrogate::load_default())?;
    let best = search.run()?;
    let cs = search.cache_stats();
    println!(
        "search finished in {:.1}s ({} candidate evaluations, {} simulated, \
         cache hit-rate {:.1}%)",
        t0.elapsed().as_secs_f64(),
        search.trace.evaluations,
        search.sims_run(),
        100.0 * cs.hit_rate()
    );

    // Figure-5-style trajectory (compressed).
    let drop = search.trace.pct_drop();
    for (g, d) in drop.iter().enumerate().step_by((drop.len() / 12).max(1)) {
        println!("  gen {g:>4}: criterion drop {d:>7.2}%");
    }

    autorac::report::fig6(&best.genome);

    // The Pareto view the scalar criterion hides: the archived front and
    // its knee (best balanced trade-off across all four objectives).
    println!(
        "Pareto front: {} points (capacity {})",
        search.archive.len(),
        search.archive.capacity()
    );
    if let Some(knee) = search.archive.knee() {
        println!(
            "  knee: {} | loss {:.4} | 1/thr {:.3e} | area {:.2} mm² | power {:.0} mW",
            knee.genome.name,
            knee.objectives[0],
            knee.objectives[1],
            knee.objectives[2],
            knee.objectives[3]
        );
    }

    // Head-to-head against the hand-crafted reference.
    let tech = TechParams::default();
    let wl = Workload::default();
    let ours = simulate(&map_genome(&best.genome, &tech, MapStyle::Smart)?, None, &wl);
    let manual = simulate(
        &map_genome(&nasrec_like("criteo"), &tech, MapStyle::Smart)?,
        None,
        &wl,
    );
    println!("\nsearched vs hand-crafted (same smart mapping):");
    println!(
        "  throughput  {:.0} vs {:.0} inf/s ({:+.1}%)",
        ours.throughput_rps,
        manual.throughput_rps,
        100.0 * (ours.throughput_rps / manual.throughput_rps - 1.0)
    );
    println!(
        "  area        {:.2} vs {:.2} mm² ({:+.1}%)",
        ours.area_mm2,
        manual.area_mm2,
        100.0 * (ours.area_mm2 / manual.area_mm2 - 1.0)
    );
    println!(
        "  power       {:.2} vs {:.2} W ({:+.1}%)",
        ours.power_mw / 1e3,
        manual.power_mw / 1e3,
        100.0 * (ours.power_mw / manual.power_mw - 1.0)
    );
    println!(
        "  surrogate LogLoss {:.4} (criterion {:.4})",
        best.test_loss, best.criterion
    );
    best.genome
        .save(std::path::Path::new("artifacts/searched_best.json"))?;
    println!("saved artifacts/searched_best.json");
    Ok(())
}
